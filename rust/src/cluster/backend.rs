//! Backend descriptors and the router's minimal HTTP/1.1 client.
//!
//! The router talks to its backends with plain `Connection: close`
//! exchanges over [`std::net::TcpStream`] — one request per connection
//! keeps the client trivial (no pooling, no chunked decoding: the flexa
//! server always answers with `Content-Length`, and SSE streams are
//! consumed until EOF). Addresses come from repeated `--backend` flags
//! (`id=host:port` or bare `host:port`) or a `--backends FILE` TOML
//! table:
//!
//! ```toml
//! [backends]
//! a = "127.0.0.1:7001"
//! b = "127.0.0.1:7002"
//! ```

use anyhow::{anyhow, bail, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Split connect/read deadlines for one router→backend exchange.
///
/// `connect` bounds the TCP handshake (a dead host fails fast);
/// `read` bounds each subsequent read/write (a live-but-slow solve may
/// legitimately take much longer than a SYN/ACK). A bare
/// [`Duration`] converts into a uniform pair, so call sites that don't
/// care about the distinction can keep passing one value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Timeouts {
    pub connect: Duration,
    pub read: Duration,
}

impl Timeouts {
    pub fn new(connect: Duration, read: Duration) -> Self {
        Timeouts { connect, read }
    }
}

impl From<Duration> for Timeouts {
    fn from(d: Duration) -> Self {
        Timeouts { connect: d, read: d }
    }
}

/// One backend: a stable id (ring identity, metrics label) + address.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackendSpec {
    pub id: String,
    pub addr: String,
}

/// Parse one `--backend` value: `id=host:port` names the backend
/// explicitly; a bare `host:port` uses the address as the id. The id is
/// the ring identity — keep it stable across restarts or placements
/// move.
pub fn parse_backend_arg(arg: &str) -> Result<BackendSpec> {
    let (id, addr) = match arg.split_once('=') {
        Some((id, addr)) => (id.trim(), addr.trim()),
        None => (arg.trim(), arg.trim()),
    };
    if id.is_empty() || addr.is_empty() {
        bail!("--backend must be `host:port` or `id=host:port`, got `{arg}`");
    }
    if !addr.contains(':') {
        bail!("backend address `{addr}` must be `host:port`");
    }
    Ok(BackendSpec { id: id.to_string(), addr: addr.to_string() })
}

/// Parse a `--backends FILE` TOML table (see the module docs).
pub fn parse_backends_file(text: &str) -> Result<Vec<BackendSpec>> {
    let doc = crate::config::toml::parse(text).map_err(|e| anyhow!("{e}"))?;
    let mut out = Vec::new();
    for (key, value) in &doc {
        let Some(id) = key.strip_prefix("backends.") else {
            bail!("unknown key `{key}` in backends file (expected [backends] id = \"host:port\")");
        };
        let addr = value
            .as_str()
            .ok_or_else(|| anyhow!("backend `{id}`: address must be a string"))?;
        out.push(parse_backend_arg(&format!("{id}={addr}"))?);
    }
    if out.is_empty() {
        bail!("backends file defines no backends (expected [backends] id = \"host:port\")");
    }
    Ok(out)
}

/// A buffered backend response.
#[derive(Debug)]
pub struct HttpReply {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpReply {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

fn connect(addr: &str, timeouts: Timeouts) -> Result<TcpStream> {
    match crate::chaos::fault("backend.connect") {
        crate::chaos::Fault::None => {}
        crate::chaos::Fault::Reset => bail!("backend `{addr}`: connect failed: injected reset"),
        crate::chaos::Fault::Slow(delay) => std::thread::sleep(delay),
    }
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| anyhow!("backend `{addr}`: cannot resolve: {e}"))?
        .next()
        .ok_or_else(|| anyhow!("backend `{addr}`: no address"))?;
    let stream = TcpStream::connect_timeout(&sock, timeouts.connect)
        .map_err(|e| anyhow!("backend `{addr}`: connect failed: {e}"))?;
    stream.set_read_timeout(Some(timeouts.read))?;
    stream.set_write_timeout(Some(timeouts.read))?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

fn write_head(
    w: &mut impl Write,
    method: &str,
    path: &str,
    addr: &str,
    headers: &[(String, String)],
    body_len: Option<usize>,
) -> std::io::Result<()> {
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    for (name, value) in headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    if let Some(len) = body_len {
        head.push_str(&format!("Content-Length: {len}\r\nContent-Type: application/json\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())
}

/// Read a response head: status line + headers, stopping at the blank
/// line; the reader is left positioned at the body.
fn read_head(reader: &mut BufReader<TcpStream>) -> Result<(u16, Vec<(String, String)>)> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        bail!("backend closed the connection before responding");
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed backend status line `{}`", line.trim_end()))?;
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("backend closed the connection mid-headers");
        }
        if h == "\r\n" || h == "\n" {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    Ok((status, headers))
}

/// One buffered request/response exchange with a backend.
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(String, String)],
    body: Option<&[u8]>,
    timeouts: impl Into<Timeouts>,
) -> Result<HttpReply> {
    let stream = connect(addr, timeouts.into())?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    write_head(&mut writer, method, path, addr, headers, body.map(<[u8]>::len))?;
    if let Some(b) = body {
        writer.write_all(b)?;
    }
    writer.flush()?;
    match crate::chaos::fault("backend.read") {
        crate::chaos::Fault::None => {}
        crate::chaos::Fault::Reset => bail!("backend `{addr}`: read failed: injected reset"),
        crate::chaos::Fault::Slow(delay) => std::thread::sleep(delay),
    }
    let (status, headers) = read_head(&mut reader)?;
    let mut body = Vec::new();
    match headers.iter().find(|(k, _)| k == "content-length") {
        Some((_, len)) => {
            let len: usize =
                len.parse().map_err(|_| anyhow!("bad backend Content-Length `{len}`"))?;
            body.resize(len, 0);
            reader.read_exact(&mut body)?;
        }
        None => {
            reader.read_to_end(&mut body)?;
        }
    }
    Ok(HttpReply { status, headers, body })
}

/// Open a streaming GET (SSE proxying): returns once the head is read,
/// leaving the reader positioned at the event stream. Reads time out at
/// `timeouts.read` per chunk — the caller's loop treats timeouts as "no
/// data yet", not as stream end.
pub fn open_stream(
    addr: &str,
    path: &str,
    headers: &[(String, String)],
    timeouts: impl Into<Timeouts>,
) -> Result<(u16, Vec<(String, String)>, BufReader<TcpStream>)> {
    let stream = connect(addr, timeouts.into())?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    write_head(&mut writer, "GET", path, addr, headers, None)?;
    writer.flush()?;
    let (status, headers) = read_head(&mut reader)?;
    Ok((status, headers, reader))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_args_parse_ids_and_addresses() {
        let b = parse_backend_arg("127.0.0.1:7001").unwrap();
        assert_eq!((b.id.as_str(), b.addr.as_str()), ("127.0.0.1:7001", "127.0.0.1:7001"));
        let b = parse_backend_arg("a=127.0.0.1:7001").unwrap();
        assert_eq!((b.id.as_str(), b.addr.as_str()), ("a", "127.0.0.1:7001"));
        assert!(parse_backend_arg("").is_err());
        assert!(parse_backend_arg("a=").is_err());
        assert!(parse_backend_arg("a=no-port").is_err());
    }

    #[test]
    fn backends_file_parses_the_toml_table() {
        let list = parse_backends_file(
            "# two nodes\n[backends]\na = \"127.0.0.1:7001\"\nb = \"127.0.0.1:7002\"\n",
        )
        .unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0], BackendSpec { id: "a".into(), addr: "127.0.0.1:7001".into() });
        assert!(parse_backends_file("[backends]\n").is_err(), "empty table rejected");
        assert!(parse_backends_file("[nodes]\na = \"x:1\"\n").is_err(), "wrong table rejected");
        assert!(parse_backends_file("[backends]\na = 7\n").is_err(), "non-string rejected");
    }

    #[test]
    fn uniform_timeouts_convert_from_a_single_duration() {
        let t: Timeouts = Duration::from_millis(250).into();
        assert_eq!(t.connect, Duration::from_millis(250));
        assert_eq!(t.read, Duration::from_millis(250));
        let split = Timeouts::new(Duration::from_millis(100), Duration::from_secs(30));
        assert_ne!(split.connect, split.read);
    }
}
