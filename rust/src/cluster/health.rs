//! Backend health tracking: periodic `/healthz` probes with a
//! consecutive-failure threshold.
//!
//! A backend starts healthy (the operator listed it; routing must work
//! before the first probe lands) and becomes unhealthy after
//! `failure_threshold` consecutive probe failures — one flaky probe on
//! a loaded node must not trigger a placement storm. A single
//! successful probe restores it. Draining is an *operator* state, set
//! by `POST /v1/cluster/backends/{id}/drain`, orthogonal to health:
//! both exclude a backend from new placements, but only draining
//! triggers the warm-start hand-off.

use super::backend::{self, BackendSpec};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Probe cadence and failure tolerance.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// Time between probe rounds.
    pub interval: Duration,
    /// Per-probe connect/read timeout.
    pub timeout: Duration,
    /// Consecutive failures before a backend is marked unhealthy.
    pub failure_threshold: u32,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            interval: Duration::from_millis(500),
            timeout: Duration::from_secs(2),
            failure_threshold: 3,
        }
    }
}

/// Live state of one backend, shared between the prober, the router's
/// placement path and the topology endpoint.
#[derive(Debug)]
pub struct BackendState {
    pub spec: BackendSpec,
    healthy: AtomicBool,
    draining: AtomicBool,
    consecutive_failures: AtomicU32,
    /// Total probes sent / failed (topology view).
    pub probes: AtomicU64,
    pub probe_failures: AtomicU64,
    /// Jobs the router placed here.
    pub placed: AtomicU64,
    /// Healthy-bit flips in either direction (monotone): the cluster
    /// watchdog's flapping detector rates this counter over a window.
    pub transitions: AtomicU64,
}

impl BackendState {
    pub fn new(spec: BackendSpec) -> Self {
        Self {
            spec,
            healthy: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            consecutive_failures: AtomicU32::new(0),
            probes: AtomicU64::new(0),
            probe_failures: AtomicU64::new(0),
            placed: AtomicU64::new(0),
            transitions: AtomicU64::new(0),
        }
    }

    pub fn healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    pub fn set_draining(&self, on: bool) {
        self.draining.store(on, Ordering::Relaxed);
    }

    /// Eligible for *new* placements.
    pub fn placeable(&self) -> bool {
        self.healthy() && !self.draining()
    }

    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    /// Record one probe outcome, flipping health at the threshold.
    /// Every actual healthy-bit flip (either direction) bumps
    /// `transitions` so flapping is countable; `swap` makes the edge
    /// detection atomic against concurrent probes.
    pub fn record_probe(&self, ok: bool, threshold: u32) {
        self.probes.fetch_add(1, Ordering::Relaxed);
        if ok {
            self.consecutive_failures.store(0, Ordering::Relaxed);
            if !self.healthy.swap(true, Ordering::Relaxed) {
                self.transitions.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            self.probe_failures.fetch_add(1, Ordering::Relaxed);
            let failures = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
            if failures >= threshold.max(1) && self.healthy.swap(false, Ordering::Relaxed) {
                self.transitions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Probe one backend's `/healthz` once.
pub fn probe(state: &BackendState, config: &HealthConfig) {
    let ok = backend::request(&state.spec.addr, "GET", "/healthz", &[], None, config.timeout)
        .map(|reply| reply.status == 200)
        .unwrap_or(false);
    state.record_probe(ok, config.failure_threshold);
}

/// Spawn the prober thread: probes every backend each `interval` until
/// `stop` (checked between short sleeps, so shutdown is prompt). The
/// inter-round sleep is jittered ±25% by a seeded PRNG so that several
/// routers probing the same fleet don't synchronize their probe bursts;
/// the jitter stream is deterministic per process (seeded from the
/// process id), keeping a single router's cadence reproducible.
pub fn spawn_prober(
    backends: Arc<Vec<Arc<BackendState>>>,
    config: HealthConfig,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("flexa-cluster-health".to_string())
        .spawn(move || {
            let mut rng =
                crate::prng::Xoshiro256pp::seed_from_u64(0x9E1A_7C4D ^ u64::from(std::process::id()));
            while !stop.load(Ordering::Relaxed) {
                for b in backends.iter() {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    probe(b, &config);
                }
                let interval = jittered(config.interval, &mut rng);
                let mut waited = Duration::ZERO;
                while waited < interval && !stop.load(Ordering::Relaxed) {
                    let step = Duration::from_millis(25).min(interval - waited);
                    std::thread::sleep(step);
                    waited += step;
                }
            }
        })
        .expect("spawn cluster health prober")
}

/// Scale `interval` by a uniform factor in [0.75, 1.25).
fn jittered(interval: Duration, rng: &mut crate::prng::Xoshiro256pp) -> Duration {
    interval.mul_f64(0.75 + 0.5 * rng.next_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> BackendState {
        BackendState::new(BackendSpec { id: "a".into(), addr: "127.0.0.1:1".into() })
    }

    /// Health flips only at the consecutive-failure threshold, and one
    /// success restores it (and resets the failure streak).
    #[test]
    fn threshold_and_recovery_semantics() {
        let b = state();
        assert!(b.healthy(), "listed backends start healthy");
        b.record_probe(false, 3);
        b.record_probe(false, 3);
        assert!(b.healthy(), "below threshold stays healthy");
        b.record_probe(false, 3);
        assert!(!b.healthy(), "threshold reached");
        b.record_probe(true, 3);
        assert!(b.healthy(), "one success restores");
        assert_eq!(b.consecutive_failures(), 0);
        b.record_probe(false, 3);
        assert!(b.healthy(), "streak restarted from zero");
        assert_eq!(b.probes.load(Ordering::Relaxed), 5);
        assert_eq!(b.probe_failures.load(Ordering::Relaxed), 4);
        assert_eq!(
            b.transitions.load(Ordering::Relaxed),
            2,
            "one down flip + one recovery; repeat probes in one state do not count"
        );
    }

    /// Draining is orthogonal to health: a draining backend can be
    /// healthy yet not placeable.
    #[test]
    fn draining_excludes_from_placement_without_touching_health() {
        let b = state();
        b.set_draining(true);
        assert!(b.healthy() && !b.placeable());
        b.set_draining(false);
        assert!(b.placeable());
    }

    /// A real probe against a dead port records a failure (port 1 on
    /// loopback refuses instantly).
    #[test]
    fn probe_against_refused_port_counts_a_failure() {
        let b = state();
        let cfg = HealthConfig {
            timeout: Duration::from_millis(300),
            failure_threshold: 1,
            ..HealthConfig::default()
        };
        probe(&b, &cfg);
        assert!(!b.healthy());
        assert_eq!(b.probe_failures.load(Ordering::Relaxed), 1);
    }

    /// The jitter factor stays inside [0.75, 1.25) so probes desync
    /// without drifting far from the configured cadence.
    #[test]
    fn probe_jitter_is_bounded() {
        let mut rng = crate::prng::Xoshiro256pp::seed_from_u64(7);
        let interval = Duration::from_millis(400);
        for _ in 0..64 {
            let j = jittered(interval, &mut rng);
            assert!(j >= Duration::from_millis(300) && j < Duration::from_millis(500), "{j:?}");
        }
    }
}
