//! The cluster router: request routing, job placement, proxying,
//! draining, metrics aggregation and the accept loop.
//!
//! | method | path                                | purpose                                    |
//! |--------|-------------------------------------|--------------------------------------------|
//! | POST   | `/v1/jobs`                          | place by warm-start fingerprint (or split) |
//! | GET    | `/v1/jobs/{id}`                     | proxy to the owning backend / split status |
//! | GET    | `/v1/jobs/{id}/events`              | SSE proxy (or synthesized split stream)    |
//! | DELETE | `/v1/jobs/{id}`                     | cancel at the owning backend / split job   |
//! | GET    | `/v1/cluster`                       | topology + health/alert/SLO rollup         |
//! | GET    | `/v1/alerts`                        | router watchdog alerts (active + recent)   |
//! | POST   | `/v1/cluster/backends/{id}/drain`   | drain + warm-start hand-off to successors  |
//! | DELETE | `/v1/cluster/backends/{id}/drain`   | cancel a drain (resume placements)         |
//! | GET    | `/v1/registry`                      | proxied from the first placeable backend   |
//! | GET    | `/v1/debug/trace`                   | merged router + backend trace-event JSON   |
//! | GET    | `/metrics`                          | summed backend series + router families    |
//! | GET    | `/healthz`                          | router liveness + healthy-backend count    |
//!
//! Placement hashes the job's *warm-start fingerprint* — the same
//! λ-excluded FNV key the backend cache uses — onto the consistent-hash
//! [`Ring`], so every λ of a sweep lands on the node already holding the
//! sweep's cached iterate. The fingerprint requires building the problem
//! once on the router; builds are memoized per λ-stripped spec, so a
//! 100-λ sweep pays one build. Jobs the jobfile grammar can't fingerprint
//! fall back to an FNV hash of the spec's debug form (stable within a
//! router process, which is all placement needs).
//!
//! Tenant auth stays at the backends: the router forwards
//! `Authorization` verbatim and never holds tokens. Split jobs are the
//! one exception — the router itself answers for them, labeled with the
//! job line's `tenant` key.
//!
//! ## Crash tolerance
//!
//! Three mechanisms keep accepted jobs alive through backend deaths:
//!
//! * **Warm-start replication** — every warm-start placement enqueues an
//!   async copy of the placement key's cache entry from the owner to its
//!   ring successor (`POST /v1/store/replicate` on the successor), so a
//!   failover landing there finds the sweep's iterate already warm.
//! * **Job failover** — the router remembers each proxied job's original
//!   body, identity and a router-minted idempotency key. When the owner
//!   dies (prober verdict, or a failed poll/stream), the job re-POSTs to
//!   the next ring successor; deterministic re-runs make the replayed
//!   result — and the SSE frame sequence — bit-identical, and the
//!   idempotency key makes a re-POST racing a slow-but-alive backend
//!   collapse into the copy it already runs.
//! * **Local degradation** — with *every* backend unplaceable, a
//!   registry-spec job is solved on the router itself (`backend`
//!   reported as `router-local`), so the cluster answers until capacity
//!   returns.

use super::backend::{self, BackendSpec, Timeouts};
use super::health::{spawn_prober, BackendState, HealthConfig};
use super::ring::Ring;
use super::split::{self, SplitConfig, SplitJob};
use crate::api::{Registry, Session};
use crate::http::parser::{self, Limits, Request};
use crate::http::router::{status_json, Response};
use crate::serve::cache::{fingerprint, Fnv};
use crate::serve::jobfile::{esc, num, parse_job_line, Json};
use crate::serve::scheduler::{JobOutcome, JobProblem, JobSpec};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Router sizing and behavior.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Virtual points per backend on the hash ring.
    pub replicas: usize,
    pub health: HealthConfig,
    pub split: SplitConfig,
    /// Concurrent connection threads; further accepts wait.
    pub max_connections: usize,
    pub max_head_bytes: usize,
    pub max_body_bytes: usize,
    /// TCP connect budget for any router→backend exchange (a dead host
    /// should fail fast; reads get the longer `proxy_timeout`).
    pub connect_timeout: Duration,
    /// Per-request read/write timeout when proxying to a backend.
    pub proxy_timeout: Duration,
    /// Replication retry budget: `attempts × backoff` bounds how long
    /// the replicator chases a warm-start entry that hasn't been
    /// written yet (the job may still be solving).
    pub replicate_attempts: u32,
    pub replicate_backoff: Duration,
    /// Solve registry-spec jobs on the router itself when no backend is
    /// placeable, instead of refusing with 503.
    pub local_fallback: bool,
    /// One structured JSON access-log line per request on stderr.
    pub access_log: bool,
    /// Window over which the cluster watchdog rates health flips and
    /// failovers.
    pub watch_window: Duration,
    /// Healthy-bit flips within the window before `backend-flapping`
    /// fires.
    pub flap_threshold: u64,
    /// Job failovers within the window before `failover-spike` fires.
    pub failover_spike_threshold: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            replicas: 64,
            health: HealthConfig::default(),
            split: SplitConfig::default(),
            max_connections: 64,
            max_head_bytes: 16 << 10,
            max_body_bytes: 1 << 20,
            connect_timeout: Duration::from_secs(2),
            proxy_timeout: Duration::from_secs(30),
            replicate_attempts: 40,
            replicate_backoff: Duration::from_millis(250),
            local_fallback: true,
            access_log: true,
            watch_window: Duration::from_secs(60),
            flap_threshold: 3,
            failover_spike_threshold: 3,
        }
    }
}

impl ClusterConfig {
    /// The split connect/read budget for router→backend exchanges.
    fn timeouts(&self) -> Timeouts {
        Timeouts::new(self.connect_timeout, self.proxy_timeout)
    }
}

/// Everything needed to re-dispatch a proxied job if its backend dies:
/// the original body and pass-through identity, the placement key (the
/// failover walk resumes from the same ring order), and the
/// router-minted idempotency key that keeps a re-POST from double-
/// running on a backend that already accepted it.
struct ProxiedJob {
    backend: usize,
    remote: u64,
    key: u64,
    idem: String,
    body: Vec<u8>,
    auth: Vec<(String, String)>,
    /// Last observed state was terminal — never re-dispatch.
    done: bool,
    /// A failover for this job is in flight on another thread.
    failing: bool,
    failovers: u32,
}

/// Where a router-issued job id points.
enum RoutedJob {
    /// Proxied to a backend (re-dispatchable on its death).
    Proxied(ProxiedJob),
    /// Driven by the router's split loop.
    Split(Arc<SplitJob>),
    /// All-backends-down degradation: solved on the router itself.
    Local(Arc<SplitJob>),
}

/// One queued warm-start replication: copy `key`'s cache entry from
/// `source` to its ring successor, retrying on backoff until the entry
/// exists (the job may still be solving) or the budget runs out.
struct ReplTask {
    source: usize,
    key: u64,
    auth: Vec<(String, String)>,
    attempts: u32,
    not_before: Instant,
}

/// Shared router context.
pub struct ClusterState {
    pub backends: Arc<Vec<Arc<BackendState>>>,
    pub ring: Ring,
    pub config: ClusterConfig,
    /// Used only to build problems for fingerprinting (memoized).
    registry: Mutex<Registry>,
    fingerprints: Mutex<HashMap<String, u64>>,
    jobs: Mutex<HashMap<u64, RoutedJob>>,
    replication: Mutex<VecDeque<ReplTask>>,
    next_job: AtomicU64,
    pub request_seq: AtomicU64,
    pub jobs_routed: AtomicU64,
    pub jobs_split: AtomicU64,
    pub drains: AtomicU64,
    pub proxy_errors: AtomicU64,
    pub scrape_errors: AtomicU64,
    pub failovers: AtomicU64,
    pub replications: AtomicU64,
    pub replication_errors: AtomicU64,
    pub local_solves: AtomicU64,
    pub started: Instant,
    /// Router-level watchdog alerts (`backend-down`, `backend-flapping`,
    /// `failover-spike`), served at `GET /v1/alerts` and embedded in the
    /// topology view.
    pub alerts: crate::watch::AlertStore,
    /// Rate windows behind the flapping/failover-spike detectors.
    watchdog: Mutex<ClusterWatch>,
}

/// Sliding windows the cluster watchdog rates its counters over; one
/// flap window per backend plus one shared failover window.
struct ClusterWatch {
    flaps: Vec<crate::watch::RateWindow>,
    failovers: crate::watch::RateWindow,
}

impl ClusterState {
    pub fn new(specs: Vec<BackendSpec>, config: ClusterConfig) -> Self {
        let ids: Vec<String> = specs.iter().map(|s| s.id.clone()).collect();
        let ring = Ring::build(&ids, config.replicas);
        let backends: Vec<Arc<BackendState>> =
            specs.into_iter().map(|s| Arc::new(BackendState::new(s))).collect();
        let window_s = config.watch_window.as_secs_f64();
        let watchdog = ClusterWatch {
            flaps: backends.iter().map(|_| crate::watch::RateWindow::new(window_s)).collect(),
            failovers: crate::watch::RateWindow::new(window_s),
        };
        Self {
            backends: Arc::new(backends),
            ring,
            config,
            registry: Mutex::new(Registry::with_defaults()),
            fingerprints: Mutex::new(HashMap::new()),
            jobs: Mutex::new(HashMap::new()),
            replication: Mutex::new(VecDeque::new()),
            next_job: AtomicU64::new(0),
            request_seq: AtomicU64::new(0),
            jobs_routed: AtomicU64::new(0),
            jobs_split: AtomicU64::new(0),
            drains: AtomicU64::new(0),
            proxy_errors: AtomicU64::new(0),
            scrape_errors: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            replications: AtomicU64::new(0),
            replication_errors: AtomicU64::new(0),
            local_solves: AtomicU64::new(0),
            started: Instant::now(),
            alerts: crate::watch::AlertStore::new(256),
            watchdog: Mutex::new(watchdog),
        }
    }

    /// Queue an async warm-start replication, deduped on `(source, key)`
    /// — a λ-sweep submits many jobs that share one placement key, and
    /// one copy covers them all.
    fn enqueue_replication(&self, source: usize, key: u64, auth: Vec<(String, String)>) {
        if self.backends.len() < 2 {
            return;
        }
        let mut q = self.replication.lock().unwrap();
        if q.iter().any(|t| t.source == source && t.key == key) {
            return;
        }
        q.push_back(ReplTask { source, key, auth, attempts: 0, not_before: Instant::now() });
    }

    fn placeable_indices(&self) -> Vec<usize> {
        (0..self.backends.len()).filter(|&i| self.backends[i].placeable()).collect()
    }

    fn next_id(&self) -> u64 {
        self.next_job.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// The consistent-hash key for one parsed job: the warm-start
    /// fingerprint of its (λ-stripped) problem, memoized per spec so a
    /// sweep builds the problem once; anything unfingerprintable hashes
    /// its debug form.
    pub fn placement_key(&self, job: &JobSpec) -> u64 {
        if let JobProblem::Spec(spec) = &job.problem {
            let mut probe = spec.clone();
            probe.lambda = None;
            let memo_key = probe.to_toml();
            if let Some(k) = self.fingerprints.lock().unwrap().get(&memo_key) {
                return *k;
            }
            if let Ok(problem) = self.registry.lock().unwrap().build_problem(&probe) {
                let key = fingerprint(&problem);
                self.fingerprints.lock().unwrap().insert(memo_key, key);
                return key;
            }
        }
        let mut h = Fnv::new();
        h.write(format!("{:?}/{}", job.problem, job.solver.name).as_bytes());
        h.finish()
    }

    fn access_log(&self, request: &str, method: &str, path: &str, status: u16, started: Instant) {
        if !self.config.access_log {
            return;
        }
        eprintln!(
            "{{\"request\":\"{}\",\"method\":\"{}\",\"path\":\"{}\",\"status\":{status},\"duration_ms\":{:.3},\"role\":\"cluster\"}}",
            esc(request),
            esc(method),
            esc(path),
            started.elapsed().as_secs_f64() * 1e3,
        );
    }
}

/// Router dispatch outcome: a buffered response, or a stream the
/// connection loop takes over.
enum ClusterRouted {
    Response(Response),
    /// Forward (and, across failovers, resume) the owning backend's SSE
    /// stream for router job `rid`, rewriting remote → `rid` ids.
    ProxyStream { rid: u64 },
    /// Synthesize the split (or router-local) job's event stream.
    SplitStream(Arc<SplitJob>),
}

/// Headers forwarded on every proxied exchange: the request id (so one
/// id threads router and backend logs) plus the client's credential.
fn passthrough_headers(req: &Request, req_id: &str) -> Vec<(String, String)> {
    let mut h = vec![("x-flexa-request-id".to_string(), req_id.to_string())];
    if let Some(a) = req.header("authorization") {
        h.push(("Authorization".to_string(), a.to_string()));
    }
    h
}

/// One proxied exchange with `backends[idx]`, timed as a
/// `cluster.proxy` span labeled with the backend id. The thread's
/// request context stamps the span with the same id the backend logs
/// and traces under, so router and backend spans stitch.
fn proxy_exchange(
    state: &ClusterState,
    idx: usize,
    method: &str,
    path: &str,
    headers: &[(String, String)],
    body: Option<&[u8]>,
) -> Result<backend::HttpReply> {
    let target = &state.backends[idx];
    let _span = crate::obs::span_detail("cluster.proxy", &target.spec.id);
    backend::request(&target.spec.addr, method, path, headers, body, state.config.timeouts())
}

fn route(state: &Arc<ClusterState>, req: &Request, req_id: &str) -> ClusterRouted {
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let respond = ClusterRouted::Response;
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => {
            let healthy = state.backends.iter().filter(|b| b.healthy()).count();
            respond(Response::json(
                200,
                format!(
                    "{{\"status\":\"ok\",\"role\":\"cluster\",\"backends\":{},\"healthy\":{healthy}}}",
                    state.backends.len()
                ),
            ))
        }
        ("GET", ["v1", "cluster"]) => respond(Response::json(200, topology_json(state, req_id))),
        ("GET", ["v1", "alerts"]) => respond(Response::json(200, state.alerts.json())),
        ("POST", ["v1", "cluster", "backends", id, "drain"]) => {
            respond(drain(state, req, req_id, id))
        }
        ("DELETE", ["v1", "cluster", "backends", id, "drain"]) => respond(undrain(state, id)),
        ("GET", ["metrics"]) => respond(Response::text(200, aggregate_metrics(state, req_id))),
        ("GET", ["v1", "registry"]) => respond(proxy_registry(state, req, req_id)),
        ("GET", ["v1", "debug", "trace"]) => respond(debug_trace(state, req, req_id)),
        ("POST", ["v1", "jobs"]) => respond(submit(state, req, req_id)),
        ("GET", ["v1", "jobs", id]) => respond(match parse_id(id) {
            Err(r) => r,
            Ok(rid) => job_get(state, req, req_id, rid),
        }),
        ("DELETE", ["v1", "jobs", id]) => respond(match parse_id(id) {
            Err(r) => r,
            Ok(rid) => job_delete(state, req, req_id, rid),
        }),
        ("GET", ["v1", "jobs", id, "events"]) => match parse_id(id) {
            Err(r) => respond(r),
            Ok(rid) => job_events(state, req, req_id, rid),
        },
        (_, ["healthz"] | ["metrics"] | ["v1", "registry"] | ["v1", "cluster"] | ["v1", "alerts"]) => {
            respond(method_not_allowed("GET"))
        }
        (_, ["v1", "jobs"]) => respond(method_not_allowed("POST")),
        (_, ["v1", "jobs", _]) => respond(method_not_allowed("GET, DELETE")),
        (_, ["v1", "jobs", _, "events"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "cluster", "backends", _, "drain"]) => {
            respond(method_not_allowed("POST, DELETE"))
        }
        (_, ["v1", "debug", "trace"]) => respond(method_not_allowed("GET")),
        _ => respond(Response::error(404, &format!("no route for {} {}", req.method, req.path))),
    }
}

fn method_not_allowed(allow: &str) -> Response {
    Response::error(405, &format!("method not allowed (allow: {allow})"))
        .with_header("Allow", allow.to_string())
}

fn parse_id(raw: &str) -> Result<u64, Response> {
    raw.parse::<u64>()
        .map_err(|_| Response::error(400, &format!("job id must be an integer, got `{raw}`")))
}

/// `GET /v1/cluster`: the operator's topology view, now a cluster-wide
/// health rollup — each healthy backend's `/v1/alerts` and `/v1/slo`
/// bodies are embedded verbatim (scrape failures omit the keys rather
/// than failing the topology), and the router's own watchdog alerts
/// ride at the top level.
fn topology_json(state: &ClusterState, req_id: &str) -> String {
    let headers = vec![("x-flexa-request-id".to_string(), req_id.to_string())];
    let mut s = format!(
        "{{\"replicas\":{},\"split_threshold_cols\":{},\"alerts\":{},\"backends\":[",
        state.config.replicas,
        state.config.split.threshold_cols,
        state.alerts.json(),
    );
    for (i, b) in state.backends.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"id\":\"{}\",\"addr\":\"{}\",\"healthy\":{},\"draining\":{},\"consecutive_failures\":{},\"probes\":{},\"probe_failures\":{},\"placed\":{},\"transitions\":{}",
            esc(&b.spec.id),
            esc(&b.spec.addr),
            b.healthy(),
            b.draining(),
            b.consecutive_failures(),
            b.probes.load(Ordering::Relaxed),
            b.probe_failures.load(Ordering::Relaxed),
            b.placed.load(Ordering::Relaxed),
            b.transitions.load(Ordering::Relaxed),
        ));
        if b.healthy() {
            for (path, key) in [("/v1/alerts", "alerts"), ("/v1/slo", "slo")] {
                match proxy_exchange(state, i, "GET", path, &headers, None) {
                    Ok(reply) if reply.status == 200 => {
                        let body = reply.body_str();
                        // Only splice verbatim what parses back — a torn
                        // body must not corrupt the whole topology doc.
                        if Json::parse(&body).is_ok() {
                            s.push_str(&format!(",\"{key}\":{}", body.trim()));
                        } else {
                            state.scrape_errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    _ => {
                        state.scrape_errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        s.push('}');
    }
    s.push_str("]}");
    s
}

/// `POST /v1/jobs`: parse, pick split vs. proxy, place, forward.
fn submit(state: &Arc<ClusterState>, req: &Request, req_id: &str) -> Response {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t.trim(),
        Err(_) => return Response::error(400, "request body must be UTF-8 JSON"),
    };
    if text.is_empty() {
        return Response::error(400, "empty body: send one JSON job object");
    }
    let job = match parse_job_line(text) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let placeable = state.placeable_indices();
    if placeable.is_empty() && !state.config.local_fallback {
        return Response::error(503, "no healthy backend accepts placements")
            .with_header("Retry-After", "1".to_string());
    }
    let key = state.placement_key(&job);

    // Split path: big admm jobs become router-driven consensus solves.
    if let Some(plan) = split::plan(&job, placeable.len(), &state.config.split) {
        let order = state.ring.order(key);
        let targets: Vec<BackendSpec> = order
            .iter()
            .filter(|i| state.backends[**i].placeable())
            .take(plan.procs)
            .map(|i| state.backends[*i].spec.clone())
            .collect();
        if targets.len() >= 2 {
            let rid = state.next_id();
            let split_job = Arc::new(SplitJob::new(
                rid,
                job.tag.clone(),
                job.tenant.clone(),
                match &job.problem {
                    JobProblem::Spec(s) => s.kind.clone(),
                    JobProblem::Custom { name, .. } => name.clone(),
                },
                targets.len(),
            ));
            state.jobs.lock().unwrap().insert(rid, RoutedJob::Split(Arc::clone(&split_job)));
            state.jobs_split.fetch_add(1, Ordering::Relaxed);
            let auth = passthrough_headers(req, req_id);
            let x0 = job.opts.x0.clone();
            let driver_job = Arc::clone(&split_job);
            let config = state.config.split;
            let spawn = std::thread::Builder::new().name("flexa-cluster-split".to_string()).spawn(
                move || {
                    split::drive(&driver_job, &targets, &plan, x0.as_deref(), &auth, &config);
                },
            );
            if spawn.is_err() {
                split_job.request_cancel();
                return Response::error(500, "cannot spawn split driver thread");
            }
            return Response::json(
                202,
                format!(
                    "{{\"job\":{rid},\"tenant\":\"{}\",\"split\":{},\"status_url\":\"/v1/jobs/{rid}\",\"events_url\":\"/v1/jobs/{rid}/events\"}}",
                    esc(&job.tenant),
                    split_job.procs
                ),
            );
        }
    }

    // Ordinary path: the fingerprint's ring owner, walking successors on
    // connection failure so a just-died backend sheds to its neighbor
    // even before the prober notices. The router-minted idempotency key
    // rides every attempt, so re-POSTing the same body — here or at
    // failover time — collapses into a copy the backend already runs.
    let auth = passthrough_headers(req, req_id);
    let rid = state.next_id();
    let idem = format!("c{rid}-{key:016x}");
    let mut headers = auth.clone();
    headers.push(("x-flexa-idempotency-key".to_string(), idem.clone()));
    for &idx in state.ring.order(key).iter() {
        if !state.backends[idx].placeable() {
            continue;
        }
        let target = &state.backends[idx];
        let reply = match proxy_exchange(
            state,
            idx,
            "POST",
            "/v1/jobs",
            &headers,
            Some(req.body.as_slice()),
        ) {
            Ok(r) => r,
            Err(_) => {
                state.proxy_errors.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        };
        if reply.status != 202 {
            // The backend answered: its refusal (400/401/403/429 + any
            // Retry-After) passes through untouched.
            let mut resp = Response::json(reply.status, reply.body_str());
            if let Some(ra) = reply.header("retry-after") {
                resp = resp.with_header("Retry-After", ra.to_string());
            }
            return resp;
        }
        let body = match Json::parse(&reply.body_str()) {
            Ok(b) => b,
            Err(_) => return Response::error(502, "backend returned malformed submit response"),
        };
        let Some(remote) = body.get("job").and_then(Json::as_f64).map(|v| v as u64) else {
            return Response::error(502, "backend submit response missing job id");
        };
        let tenant =
            body.get("tenant").and_then(Json::as_str).unwrap_or(job.tenant.as_str()).to_string();
        state.jobs.lock().unwrap().insert(
            rid,
            RoutedJob::Proxied(ProxiedJob {
                backend: idx,
                remote,
                key,
                idem,
                body: req.body.clone(),
                auth: auth.clone(),
                done: false,
                failing: false,
                failovers: 0,
            }),
        );
        state.jobs_routed.fetch_add(1, Ordering::Relaxed);
        target.placed.fetch_add(1, Ordering::Relaxed);
        if job.warm_start {
            // Async: copy the sweep's cache entry to the ring successor
            // so a failover there starts warm.
            state.enqueue_replication(idx, key, auth);
        }
        return Response::json(
            202,
            format!(
                "{{\"job\":{rid},\"tenant\":\"{}\",\"backend\":\"{}\",\"status_url\":\"/v1/jobs/{rid}\",\"events_url\":\"/v1/jobs/{rid}/events\"}}",
                esc(&tenant),
                esc(&target.spec.id)
            ),
        );
    }
    // Nothing accepted the connection: degrade to an in-process solve so
    // the cluster keeps answering with every backend down.
    if state.config.local_fallback && matches!(job.problem, JobProblem::Spec(_)) {
        degrade_to_local(state, rid, &req.body);
        if lookup_split(state, rid).is_some() {
            return Response::json(
                202,
                format!(
                    "{{\"job\":{rid},\"tenant\":\"{}\",\"backend\":\"router-local\",\"status_url\":\"/v1/jobs/{rid}\",\"events_url\":\"/v1/jobs/{rid}/events\"}}",
                    esc(&job.tenant)
                ),
            );
        }
    }
    Response::error(503, "every eligible backend refused the connection")
        .with_header("Retry-After", "1".to_string())
}

/// Rewrite the backend's job id to the router's in a status/cancel body
/// (`status_json` bodies always open `{"job":N,`).
fn rewrite_job_id(body: &str, remote: u64, rid: u64) -> String {
    body.replacen(&format!("{{\"job\":{remote},"), &format!("{{\"job\":{rid},"), 1)
}

fn lookup(state: &ClusterState, rid: u64) -> Option<(usize, u64)> {
    match state.jobs.lock().unwrap().get(&rid) {
        Some(RoutedJob::Proxied(p)) => Some((p.backend, p.remote)),
        _ => None,
    }
}

fn lookup_split(state: &ClusterState, rid: u64) -> Option<Arc<SplitJob>> {
    match state.jobs.lock().unwrap().get(&rid) {
        Some(RoutedJob::Split(job) | RoutedJob::Local(job)) => Some(Arc::clone(job)),
        _ => None,
    }
}

fn no_such_job(rid: u64) -> Response {
    Response::error(404, &format!("no such job {rid} (never submitted, or pruned)"))
}

/// Remember that a proxied job was observed terminal, so the failover
/// sweep never re-dispatches it.
fn note_done(state: &ClusterState, rid: u64, body: &str) {
    if !body.contains("\"state\":\"finished\"") {
        return;
    }
    if let Some(RoutedJob::Proxied(p)) = state.jobs.lock().unwrap().get_mut(&rid) {
        p.done = true;
    }
}

/// Re-dispatch a proxied job whose backend died (or stopped answering):
/// re-POST the original body — same idempotency key — to the next ring
/// successor in the job's own placement order. The old copy is
/// best-effort cancelled in case the backend is slow rather than dead;
/// if it already accepted a racing re-POST, the idempotency key makes
/// the new submit collapse into that copy instead of double-running.
/// With nothing placeable the job degrades to a router-local solve
/// (when enabled); callers re-check `lookup_split` after a `None`.
fn failover_job(state: &ClusterState, rid: u64) -> Option<(usize, u64)> {
    let (old_backend, old_remote, key, idem, body, auth, failovers) = {
        let mut jobs = state.jobs.lock().unwrap();
        match jobs.get_mut(&rid) {
            Some(RoutedJob::Proxied(p)) if !p.done && !p.failing => {
                p.failing = true;
                (p.backend, p.remote, p.key, p.idem.clone(), p.body.clone(), p.auth.clone(), p.failovers)
            }
            _ => return None,
        }
    };
    let _span = crate::obs::span_detail(
        "failover.redispatch",
        &format!("job {rid} off {}", state.backends[old_backend].spec.id),
    );
    let mut headers = auth.clone();
    headers.push(("x-flexa-idempotency-key".to_string(), idem));
    let mut placed = None;
    for &idx in state.ring.order(key).iter() {
        if idx == old_backend || !state.backends[idx].placeable() {
            continue;
        }
        let reply = match proxy_exchange(state, idx, "POST", "/v1/jobs", &headers, Some(&body)) {
            Ok(r) if r.status == 202 => r,
            Ok(_) => continue,
            Err(_) => {
                state.proxy_errors.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        };
        let remote = Json::parse(&reply.body_str())
            .ok()
            .and_then(|b| b.get("job").and_then(Json::as_f64))
            .map(|v| v as u64);
        if let Some(remote) = remote {
            placed = Some((idx, remote));
            break;
        }
    }
    match placed {
        Some((idx, remote)) => {
            if let Some(RoutedJob::Proxied(p)) = state.jobs.lock().unwrap().get_mut(&rid) {
                p.backend = idx;
                p.remote = remote;
                p.failing = false;
                p.failovers = failovers + 1;
            }
            state.failovers.fetch_add(1, Ordering::Relaxed);
            state.backends[idx].placed.fetch_add(1, Ordering::Relaxed);
            // Hygiene: the old copy may still be running on a slow-but-
            // alive backend; a dead one is fine to ignore.
            let _ = proxy_exchange(
                state,
                old_backend,
                "DELETE",
                &format!("/v1/jobs/{old_remote}"),
                &auth,
                None,
            );
            Some((idx, remote))
        }
        None => {
            if state.config.local_fallback {
                degrade_to_local(state, rid, &body);
            }
            if let Some(RoutedJob::Proxied(p)) = state.jobs.lock().unwrap().get_mut(&rid) {
                p.failing = false;
            }
            None
        }
    }
}

/// All-backends-down degradation: replace the routed job with a router-
/// local in-process solve of the same spec. Only registry specs degrade
/// (a custom problem can't be rebuilt here); a no-op leaves the caller's
/// lookup unchanged, which it treats as "still unplaceable".
fn degrade_to_local(state: &ClusterState, rid: u64, body: &[u8]) {
    let Ok(text) = std::str::from_utf8(body) else { return };
    let Ok(job) = parse_job_line(text.trim()) else { return };
    let JobProblem::Spec(spec) = job.problem else { return };
    let local = Arc::new(SplitJob::labeled(
        rid,
        job.tag,
        job.tenant,
        spec.kind.clone(),
        1,
        format!("local/{}", job.solver.name),
    ));
    state.jobs.lock().unwrap().insert(rid, RoutedJob::Local(Arc::clone(&local)));
    state.local_solves.fetch_add(1, Ordering::Relaxed);
    let _span = crate::obs::span_detail("failover.local", &format!("job {rid}"));
    let driver = Arc::clone(&local);
    let solver = job.solver;
    let opts = job.opts;
    let spawned = std::thread::Builder::new().name("flexa-cluster-local".to_string()).spawn(
        move || {
            driver.mark_running();
            driver.push_event(
                "started",
                format!(
                    "{{\"event\":\"local-started\",\"job\":{},\"solver\":\"{}\"}}",
                    driver.id,
                    esc(&driver.solver)
                ),
            );
            match Session::problem(spec).solver(solver).options(opts).run() {
                Ok(run) => {
                    let r = &run.report;
                    driver.finish(
                        JobOutcome::Done {
                            converged: r.converged,
                            objective: r.objective,
                            iterations: r.iterations,
                            warm_started: false,
                        },
                        Some(r.x.clone()),
                    );
                }
                Err(e) => driver.finish(JobOutcome::Failed { error: format!("{e:#}") }, None),
            }
        },
    );
    if spawned.is_err() {
        local.finish(JobOutcome::Failed { error: "cannot spawn local solve thread".into() }, None);
    }
}

fn job_get(state: &ClusterState, req: &Request, req_id: &str, rid: u64) -> Response {
    if let Some(job) = lookup_split(state, rid) {
        return Response::json(200, status_json(&job.status(), req.query_flag("x")));
    }
    let Some((idx, remote)) = lookup(state, rid) else {
        return no_such_job(rid);
    };
    let headers = passthrough_headers(req, req_id);
    let path = |remote: u64| {
        if req.query_flag("x") {
            format!("/v1/jobs/{remote}?x=1")
        } else {
            format!("/v1/jobs/{remote}")
        }
    };
    match proxy_exchange(state, idx, "GET", &path(remote), &headers, None) {
        Ok(reply) => {
            let body = rewrite_job_id(&reply.body_str(), remote, rid);
            note_done(state, rid, &body);
            Response::json(reply.status, body)
        }
        Err(_) => {
            // The owner is gone: fail the job over and answer from the
            // successor (or from the degraded local job) in the same
            // request, so a poller never sees the crash.
            state.proxy_errors.fetch_add(1, Ordering::Relaxed);
            if let Some((idx2, remote2)) = failover_job(state, rid) {
                return match proxy_exchange(state, idx2, "GET", &path(remote2), &headers, None) {
                    Ok(reply) => {
                        let body = rewrite_job_id(&reply.body_str(), remote2, rid);
                        note_done(state, rid, &body);
                        Response::json(reply.status, body)
                    }
                    Err(e) => Response::error(
                        502,
                        &format!(
                            "backend `{}` unreachable after failover: {e:#}",
                            state.backends[idx2].spec.id
                        ),
                    ),
                };
            }
            if let Some(job) = lookup_split(state, rid) {
                return Response::json(200, status_json(&job.status(), req.query_flag("x")));
            }
            Response::error(
                502,
                &format!(
                    "backend `{}` unreachable and no failover target",
                    state.backends[idx].spec.id
                ),
            )
        }
    }
}

fn job_delete(state: &ClusterState, req: &Request, req_id: &str, rid: u64) -> Response {
    if let Some(job) = lookup_split(state, rid) {
        return if job.request_cancel() {
            Response::json(200, format!("{{\"job\":{rid},\"cancel\":\"requested\"}}"))
        } else {
            Response::error(404, &format!("no such job {rid}"))
        };
    }
    let Some((idx, remote)) = lookup(state, rid) else {
        return no_such_job(rid);
    };
    match proxy_exchange(
        state,
        idx,
        "DELETE",
        &format!("/v1/jobs/{remote}"),
        &passthrough_headers(req, req_id),
        None,
    ) {
        Ok(reply) => Response::json(reply.status, rewrite_job_id(&reply.body_str(), remote, rid)),
        Err(e) => {
            // The client no longer wants the job — mark it done so the
            // failover sweep doesn't resurrect it on a successor.
            state.proxy_errors.fetch_add(1, Ordering::Relaxed);
            if let Some(RoutedJob::Proxied(p)) = state.jobs.lock().unwrap().get_mut(&rid) {
                p.done = true;
            }
            Response::error(
                502,
                &format!(
                    "backend `{}` unreachable; job {rid} dropped from failover tracking: {e:#}",
                    state.backends[idx].spec.id
                ),
            )
        }
    }
}

fn job_events(state: &Arc<ClusterState>, req: &Request, req_id: &str, rid: u64) -> ClusterRouted {
    if let Some(job) = lookup_split(state, rid) {
        return ClusterRouted::SplitStream(job);
    }
    if lookup(state, rid).is_none() {
        return ClusterRouted::Response(Response::error(
            404,
            &format!("no event stream for job {rid} (never submitted, or pruned)"),
        ));
    }
    let _ = (req, req_id);
    ClusterRouted::ProxyStream { rid }
}

/// `GET /v1/registry`: the registry is identical on every backend;
/// proxy from the first one that answers.
fn proxy_registry(state: &ClusterState, req: &Request, req_id: &str) -> Response {
    for (i, b) in state.backends.iter().enumerate() {
        if !b.healthy() {
            continue;
        }
        if let Ok(reply) =
            proxy_exchange(state, i, "GET", "/v1/registry", &passthrough_headers(req, req_id), None)
        {
            return Response::json(reply.status, reply.body_str());
        }
        state.proxy_errors.fetch_add(1, Ordering::Relaxed);
    }
    Response::error(503, "no healthy backend to serve the registry")
}

/// `GET /v1/debug/trace`: the router's own spans (pid 0) merged with
/// every healthy backend's export (pid i+1). Each node renders exactly
/// `{"traceEvents":[...]}`, so backend documents splice in via a
/// prefix/suffix strip ([`crate::obs::trace::inner_events`]) plus a
/// textual pid rewrite — no JSON re-parse on the hot path. Clock
/// domains differ per node; cross-node correlation rides the shared
/// request id in each event's `args`.
fn debug_trace(state: &ClusterState, req: &Request, req_id: &str) -> Response {
    let since_ms =
        req.query_value("since_ms").and_then(|v| v.parse::<u64>().ok()).unwrap_or(0);
    let own = crate::obs::snapshot(since_ms.saturating_mul(1000));
    let mut events = String::new();
    crate::obs::trace::render_events_into(&own, 0, &mut events);
    let path = format!("/v1/debug/trace?since_ms={since_ms}");
    let headers = vec![("x-flexa-request-id".to_string(), req_id.to_string())];
    for (i, b) in state.backends.iter().enumerate() {
        if !b.healthy() {
            continue;
        }
        let reply = match proxy_exchange(state, i, "GET", &path, &headers, None) {
            Ok(r) if r.status == 200 => r,
            _ => {
                state.scrape_errors.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        };
        let body = reply.body_str();
        let Some(inner) = crate::obs::trace::inner_events(&body) else {
            state.scrape_errors.fetch_add(1, Ordering::Relaxed);
            continue;
        };
        if inner.is_empty() {
            continue;
        }
        // Backends render themselves as pid 0; re-home under pid i+1.
        // The quoted pattern cannot occur inside a string value (values
        // are escaped), so a plain replace is exact.
        let rehomed = inner.replace("\"pid\":0,", &format!("\"pid\":{},", i + 1));
        if !events.is_empty() {
            events.push(',');
        }
        events.push_str(&rehomed);
    }
    Response::json(200, format!("{{\"traceEvents\":[{events}]}}"))
}

/// `POST /v1/cluster/backends/{id}/drain`: stop new placements on the
/// backend, pull its warm-start snapshot, and re-place every cache entry
/// on its ring successor so follow-up sweep jobs keep their warm starts.
fn drain(state: &ClusterState, req: &Request, req_id: &str, id: &str) -> Response {
    let Some(drained) = state.backends.iter().position(|b| b.spec.id == id) else {
        return Response::error(404, &format!("no backend `{id}`"));
    };
    state.backends[drained].set_draining(true);
    state.drains.fetch_add(1, Ordering::Relaxed);
    let headers = passthrough_headers(req, req_id);

    // Pull the snapshot. Failure keeps the backend draining (placements
    // have stopped) but reports the hand-off as incomplete.
    let reply = match proxy_exchange(state, drained, "GET", "/v1/cache/snapshot", &headers, None) {
        Ok(r) if r.status == 200 => r,
        Ok(r) => {
            return Response::error(
                502,
                &format!(
                    "backend `{id}` is draining but its snapshot request failed with {}: {}",
                    r.status,
                    r.body_str().trim()
                ),
            )
        }
        Err(e) => {
            state.proxy_errors.fetch_add(1, Ordering::Relaxed);
            return Response::error(
                502,
                &format!("backend `{id}` is draining but unreachable for hand-off: {e:#}"),
            );
        }
    };
    let snapshot = match Json::parse(&reply.body_str()) {
        Ok(s) => s,
        Err(e) => return Response::error(502, &format!("backend `{id}` snapshot is malformed: {e:#}")),
    };
    let Some(Json::Arr(entries)) = snapshot.get("entries") else {
        return Response::error(502, &format!("backend `{id}` snapshot carries no entries"));
    };

    // Group entries by their new ring owner (the successor placement
    // with the drained backend excluded).
    let mut grouped: HashMap<usize, Vec<String>> = HashMap::new();
    let mut unplaced = 0usize;
    for entry in entries {
        let Some(key) = entry.get("key").and_then(Json::as_str).and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        let target = state
            .ring
            .place(key, |i| i != drained && state.backends[i].placeable());
        match target {
            Some(t) => grouped.entry(t).or_default().push(render_snapshot_entry(entry)),
            None => unplaced += 1,
        }
    }

    let mut moved = Vec::new();
    for (target, lines) in &grouped {
        let body = format!("{{\"entries\":[{}]}}", lines.join(","));
        let ok = proxy_exchange(state, *target, "POST", "/v1/cache/snapshot", &headers, Some(body.as_bytes()))
            .map(|r| r.status == 200)
        .unwrap_or_else(|_| {
            state.proxy_errors.fetch_add(1, Ordering::Relaxed);
            false
        });
        moved.push(format!(
            "{{\"to\":\"{}\",\"entries\":{},\"imported\":{ok}}}",
            esc(&state.backends[*target].spec.id),
            lines.len()
        ));
    }
    Response::json(
        200,
        format!(
            "{{\"backend\":\"{}\",\"draining\":true,\"entries\":{},\"unplaced\":{unplaced},\"moved\":[{}]}}",
            esc(id),
            entries.len(),
            moved.join(",")
        ),
    )
}

fn undrain(state: &ClusterState, id: &str) -> Response {
    let Some(b) = state.backends.iter().find(|b| b.spec.id == id) else {
        return Response::error(404, &format!("no backend `{id}`"));
    };
    b.set_draining(false);
    Response::json(200, format!("{{\"backend\":\"{}\",\"draining\":false}}", esc(id)))
}

/// Re-render one parsed snapshot entry in the wire format (keys as
/// strings, floats in shortest round-trip form, so the hand-off is
/// bit-exact end to end).
fn render_snapshot_entry(entry: &Json) -> String {
    let key = entry.get("key").and_then(Json::as_str).unwrap_or("0");
    let mut s = format!("{{\"key\":\"{}\"", esc(key));
    if let Some(Json::Arr(xs)) = entry.get("x") {
        s.push_str(",\"x\":[");
        for (i, v) in xs.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&num(v.as_f64().unwrap_or(f64::NAN)));
        }
        s.push(']');
    }
    for field in ["tau", "lipschitz"] {
        if let Some(v) = entry.get(field).and_then(Json::as_f64) {
            s.push_str(&format!(",\"{field}\":{}", num(v)));
        }
    }
    s.push('}');
    s
}

/// `GET /metrics`: scrape every healthy backend, sum identical series,
/// and append the router's own `flexa_cluster_*` families. Backend
/// `# HELP`/`# TYPE` comments are dropped (the series keep their names,
/// which is what scrape configs and the tests match on).
fn aggregate_metrics(state: &ClusterState, req_id: &str) -> String {
    let mut order: Vec<String> = Vec::new();
    let mut sums: HashMap<String, f64> = HashMap::new();
    for (i, b) in state.backends.iter().enumerate() {
        if !b.healthy() {
            continue;
        }
        let text = match proxy_exchange(
            state,
            i,
            "GET",
            "/metrics",
            &[("x-flexa-request-id".to_string(), req_id.to_string())],
            None,
        ) {
            Ok(r) if r.status == 200 => r.body_str(),
            _ => {
                state.scrape_errors.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, value)) = line.rsplit_once(' ') else {
                continue;
            };
            let Ok(value) = value.parse::<f64>() else {
                continue;
            };
            let key = key.trim();
            if !sums.contains_key(key) {
                order.push(key.to_string());
            }
            *sums.entry(key.to_string()).or_insert(0.0) += value;
        }
    }
    let mut out = String::new();
    for key in &order {
        out.push_str(&format!("{key} {}\n", num(sums[key])));
    }
    out.push_str("# HELP flexa_cluster_backends_total Backends configured on the router.\n# TYPE flexa_cluster_backends_total gauge\n");
    out.push_str(&format!("flexa_cluster_backends_total {}\n", state.backends.len()));
    let healthy = state.backends.iter().filter(|b| b.healthy()).count();
    let draining = state.backends.iter().filter(|b| b.draining()).count();
    out.push_str(&format!("flexa_cluster_backends_healthy {healthy}\n"));
    out.push_str(&format!("flexa_cluster_backends_draining {draining}\n"));
    out.push_str(&format!(
        "flexa_cluster_jobs_routed_total {}\n",
        state.jobs_routed.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "flexa_cluster_jobs_split_total {}\n",
        state.jobs_split.load(Ordering::Relaxed)
    ));
    out.push_str(&format!("flexa_cluster_drains_total {}\n", state.drains.load(Ordering::Relaxed)));
    out.push_str(&format!(
        "flexa_cluster_proxy_errors_total {}\n",
        state.proxy_errors.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "flexa_cluster_scrape_errors_total {}\n",
        state.scrape_errors.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "flexa_cluster_failovers_total {}\n",
        state.failovers.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "flexa_cluster_replications_total {}\n",
        state.replications.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "flexa_cluster_replication_errors_total {}\n",
        state.replication_errors.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "flexa_cluster_local_solves_total {}\n",
        state.local_solves.load(Ordering::Relaxed)
    ));
    for b in state.backends.iter() {
        out.push_str(&format!(
            "flexa_cluster_backend_placed_total{{backend=\"{}\"}} {}\n",
            esc(&b.spec.id),
            b.placed.load(Ordering::Relaxed)
        ));
    }
    // Router watchdog alert families. The backend `flexa_alerts_*`
    // series sum textually above because every node emits the full
    // fixed kind set; these are the *router's own* alerts.
    let alert_counts = state.alerts.counts();
    out.push_str("# HELP flexa_cluster_alerts_total Router watchdog alerts fired by kind.\n# TYPE flexa_cluster_alerts_total counter\n");
    for (label, fired, _) in &alert_counts {
        out.push_str(&format!("flexa_cluster_alerts_total{{kind=\"{label}\"}} {fired}\n"));
    }
    out.push_str("# HELP flexa_cluster_alerts_active Router watchdog alerts currently firing by kind.\n# TYPE flexa_cluster_alerts_active gauge\n");
    for (label, _, active) in &alert_counts {
        out.push_str(&format!("flexa_cluster_alerts_active{{kind=\"{label}\"}} {active}\n"));
    }
    out.push_str(&format!(
        "flexa_cluster_uptime_seconds {:.3}\n",
        state.started.elapsed().as_secs_f64()
    ));
    out
}

/// The replication/failover worker: drains the warm-start replication
/// queue (each task copies one cache entry from its source backend to
/// the ring successor) and, every ~500 ms, sweeps the job table for
/// live jobs stranded on unhealthy backends so failover doesn't wait
/// for the next client poll.
fn spawn_replicator(
    state: Arc<ClusterState>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("flexa-cluster-repl".to_string())
        .spawn(move || {
            let mut last_sweep = Instant::now();
            while !stop.load(Ordering::Relaxed) && !crate::http::shutdown_signal_fired() {
                if last_sweep.elapsed() >= Duration::from_millis(500) {
                    last_sweep = Instant::now();
                    failover_sweep(&state);
                    watch_sweep(
                        &state,
                        state.started.elapsed().as_secs_f64(),
                        crate::obs::now_us(),
                    );
                }
                let task = {
                    let mut q = state.replication.lock().unwrap();
                    let now = Instant::now();
                    match q.iter().position(|t| t.not_before <= now) {
                        Some(i) => q.remove(i),
                        None => None,
                    }
                };
                let Some(mut task) = task else {
                    std::thread::sleep(Duration::from_millis(25));
                    continue;
                };
                if replicate_once(&state, &task) {
                    state.replications.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                task.attempts += 1;
                if task.attempts >= state.config.replicate_attempts.max(1) {
                    state.replication_errors.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                task.not_before = Instant::now() + state.config.replicate_backoff;
                state.replication.lock().unwrap().push_back(task);
            }
        })
        .expect("spawn cluster replicator thread")
}

/// Re-dispatch every live proxied job stranded on an unhealthy backend.
fn failover_sweep(state: &ClusterState) {
    let stranded: Vec<u64> = {
        let jobs = state.jobs.lock().unwrap();
        jobs.iter()
            .filter_map(|(rid, j)| match j {
                RoutedJob::Proxied(p)
                    if !p.done && !p.failing && !state.backends[p.backend].healthy() =>
                {
                    Some(*rid)
                }
                _ => None,
            })
            .collect()
    };
    for rid in stranded {
        failover_job(state, rid);
    }
}

/// One cluster-watchdog pass: fire/resolve `backend-down` per health
/// bit, rate health flips into `backend-flapping`, and rate failover
/// redispatches into `failover-spike`. `fire` keyed on `(kind, scope)`
/// makes the pass idempotent — a condition persisting across many
/// sweeps stays ONE alert with its original `since_us`. The clock
/// arrives as parameters so tests can fabricate time.
fn watch_sweep(state: &ClusterState, now_s: f64, now_us: u64) {
    use crate::watch::AlertKind;
    let mut w = state.watchdog.lock().unwrap_or_else(|p| p.into_inner());
    for (i, b) in state.backends.iter().enumerate() {
        let scope = format!("backend:{}", b.spec.id);
        if b.healthy() {
            state.alerts.resolve(AlertKind::BackendDown, &scope, now_us);
        } else {
            state.alerts.fire(
                AlertKind::BackendDown,
                &scope,
                format!(
                    "backend `{}` unhealthy after {} consecutive probe failures",
                    b.spec.id,
                    b.consecutive_failures()
                ),
                now_us,
            );
        }
        let flips = w.flaps[i].observe(now_s, b.transitions.load(Ordering::Relaxed));
        if flips >= state.config.flap_threshold.max(1) {
            state.alerts.fire(
                AlertKind::BackendFlapping,
                &scope,
                format!(
                    "backend `{}` health flipped {flips} times in the last {:.0}s",
                    b.spec.id,
                    state.config.watch_window.as_secs_f64()
                ),
                now_us,
            );
        } else {
            state.alerts.resolve(AlertKind::BackendFlapping, &scope, now_us);
        }
    }
    let failovers = w.failovers.observe(now_s, state.failovers.load(Ordering::Relaxed));
    if failovers >= state.config.failover_spike_threshold.max(1) {
        state.alerts.fire(
            AlertKind::FailoverSpike,
            "cluster",
            format!(
                "{failovers} job failovers in the last {:.0}s",
                state.config.watch_window.as_secs_f64()
            ),
            now_us,
        );
    } else {
        state.alerts.resolve(AlertKind::FailoverSpike, "cluster", now_us);
    }
}

/// One replication attempt: pull the entry for `task.key` from the
/// source's snapshot, push it to the ring successor's replicate
/// endpoint. `false` means "retry later" — most often the entry simply
/// isn't written yet because the job is still solving.
fn replicate_once(state: &ClusterState, task: &ReplTask) -> bool {
    let source = task.source;
    if !state.backends[source].healthy() {
        return false;
    }
    let Some(target) =
        state.ring.place(task.key, |i| i != source && state.backends[i].placeable())
    else {
        return false;
    };
    let _span = crate::obs::span_detail(
        "replicate.push",
        &format!(
            "{}→{} key {:016x}",
            state.backends[source].spec.id, state.backends[target].spec.id, task.key
        ),
    );
    let path = format!("/v1/cache/snapshot?key={}", task.key);
    let reply = match proxy_exchange(state, source, "GET", &path, &task.auth, None) {
        Ok(r) if r.status == 200 => r,
        _ => return false,
    };
    let Ok(snapshot) = Json::parse(&reply.body_str()) else {
        return false;
    };
    let Some(Json::Arr(entries)) = snapshot.get("entries") else {
        return false;
    };
    if entries.is_empty() {
        return false;
    }
    let lines: Vec<String> = entries.iter().map(render_snapshot_entry).collect();
    let body = format!("{{\"entries\":[{}]}}", lines.join(","));
    match proxy_exchange(
        state,
        target,
        "POST",
        "/v1/store/replicate",
        &task.auth,
        Some(body.as_bytes()),
    ) {
        Ok(r) => r.status == 200,
        Err(_) => {
            state.proxy_errors.fetch_add(1, Ordering::Relaxed);
            false
        }
    }
}

/// The router process: bind, spawn the health prober, serve until the
/// stop flag or a shutdown signal fires.
pub struct ClusterServer {
    listener: TcpListener,
    addr: SocketAddr,
    state: Arc<ClusterState>,
    stop: Arc<AtomicBool>,
}

impl ClusterServer {
    pub fn bind(addr: &str, specs: Vec<BackendSpec>, config: ClusterConfig) -> Result<Self> {
        if specs.is_empty() {
            return Err(anyhow!("a cluster needs at least one backend"));
        }
        let mut seen = std::collections::BTreeSet::new();
        for s in &specs {
            if !seen.insert(s.id.clone()) {
                return Err(anyhow!("duplicate backend id `{}`", s.id));
            }
        }
        crate::obs::init();
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("cannot bind cluster listener on `{addr}`: {e}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        Ok(Self {
            listener,
            addr: local,
            state: Arc::new(ClusterState::new(specs, config)),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<ClusterState> {
        &self.state
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until stopped; joins the prober and waits for in-flight
    /// connections on the way out.
    pub fn run(self) -> Result<()> {
        let ClusterServer { listener, addr: _, state, stop } = self;
        let prober = spawn_prober(
            Arc::clone(&state.backends),
            state.config.health,
            Arc::clone(&stop),
        );
        let replicator = spawn_replicator(Arc::clone(&state), Arc::clone(&stop));
        let active = Arc::new(AtomicUsize::new(0));
        let should_stop = || stop.load(Ordering::Relaxed) || crate::http::shutdown_signal_fired();
        while !should_stop() {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    while active.load(Ordering::Relaxed) >= state.config.max_connections.max(1) {
                        if should_stop() {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    active.fetch_add(1, Ordering::Relaxed);
                    let conn_state = Arc::clone(&state);
                    let conn_stop = Arc::clone(&stop);
                    let conn_active = Arc::clone(&active);
                    let spawned = std::thread::Builder::new()
                        .name("flexa-cluster-conn".to_string())
                        .spawn(move || {
                            handle_connection(stream, &conn_state, &conn_stop);
                            conn_active.fetch_sub(1, Ordering::Relaxed);
                        });
                    if spawned.is_err() {
                        active.fetch_sub(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
        drop(listener);
        // Cooperative cancellation for any in-flight split jobs, then
        // wait for connection threads to finish.
        for (_, job) in state.jobs.lock().unwrap().iter() {
            if let RoutedJob::Split(j) | RoutedJob::Local(j) = job {
                j.request_cancel();
            }
        }
        while active.load(Ordering::Relaxed) > 0 {
            std::thread::sleep(Duration::from_millis(10));
        }
        let _ = prober.join();
        let _ = replicator.join();
        Ok(())
    }

    /// Run on a background thread (tests and embedding).
    pub fn spawn(self) -> SpawnedCluster {
        let addr = self.addr;
        let stop = self.stop_flag();
        let state = Arc::clone(&self.state);
        let handle = std::thread::Builder::new()
            .name("flexa-cluster-accept".to_string())
            .spawn(move || self.run())
            .expect("spawn cluster accept thread");
        SpawnedCluster { addr, stop, state, handle }
    }
}

/// Handle to a [`ClusterServer::spawn`]ed router.
pub struct SpawnedCluster {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    state: Arc<ClusterState>,
    handle: std::thread::JoinHandle<Result<()>>,
}

impl SpawnedCluster {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<ClusterState> {
        &self.state
    }

    pub fn shutdown(self) -> Result<()> {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.join().map_err(|_| anyhow!("cluster router thread panicked"))?
    }
}

/// Serve one connection: keep-alive request loop, stream takeover for
/// SSE proxying and split streams.
fn handle_connection(stream: TcpStream, state: &Arc<ClusterState>, stop: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_nodelay(true);
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    let limits = Limits {
        max_head_bytes: state.config.max_head_bytes,
        max_body_bytes: state.config.max_body_bytes,
    };
    let abort = || stop.load(Ordering::Relaxed) || crate::http::shutdown_signal_fired();
    loop {
        match parser::read_request(&mut reader, Some(&mut writer as &mut dyn Write), &limits, &abort)
        {
            Ok(None) => return,
            Ok(Some(req)) => {
                let req_id = request_id(state, &req);
                // Tenant auth lives at the backends, so router spans
                // carry only the request id.
                let _obs_ctx = crate::obs::ctx_guard(crate::obs::Ctx::request(&req_id, ""));
                let t0 = Instant::now();
                match route(state, &req, &req_id) {
                    ClusterRouted::Response(resp) => {
                        let resp = resp.with_header("x-flexa-request-id", req_id.clone());
                        let keep_alive = req.keep_alive && resp.status < 400;
                        let wrote = resp.write_to(&mut writer, keep_alive).is_ok();
                        state.access_log(&req_id, &req.method, &req.path, resp.status, t0);
                        if !wrote || !keep_alive {
                            return;
                        }
                    }
                    ClusterRouted::ProxyStream { rid } => {
                        let status =
                            proxy_stream(state, &req, &req_id, rid, &mut writer, &abort);
                        state.access_log(&req_id, &req.method, &req.path, status, t0);
                        return;
                    }
                    ClusterRouted::SplitStream(job) => {
                        let _ = split_stream(&job, &req_id, &mut writer, &abort);
                        state.access_log(&req_id, &req.method, &req.path, 200, t0);
                        return;
                    }
                }
            }
            Err(e) => {
                let req_id =
                    (state.request_seq.fetch_add(1, Ordering::Relaxed) + 1).to_string();
                let _ = Response::error(e.status, &e.message)
                    .with_header("x-flexa-request-id", format!("c{req_id}"))
                    .write_to(&mut writer, false);
                state.access_log(&format!("c{req_id}"), "-", "-", e.status, Instant::now());
                return;
            }
        }
    }
}

/// Router request ids: a well-formed incoming `x-flexa-request-id` is
/// adopted, otherwise `c{seq}` — the `c` marks router-minted ids in
/// backend logs.
fn request_id(state: &ClusterState, req: &Request) -> String {
    if let Some(incoming) = req.header("x-flexa-request-id") {
        let t = incoming.trim();
        let well_formed = !t.is_empty()
            && t.len() <= 64
            && t.bytes().all(|b| {
                b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.' || b == b':'
            });
        if well_formed {
            return t.to_string();
        }
    }
    format!("c{}", state.request_seq.fetch_add(1, Ordering::Relaxed) + 1)
}

/// Terminal frame for an unrecoverable mid-stream failure: tells the
/// client the stream ended *cleanly* — no torn frame — and where to
/// resume (re-open `/events`; the replay is deterministic).
fn retry_hint(writer: &mut TcpStream, rid: u64, sent_events: usize) -> u16 {
    let _ = write!(
        writer,
        "event: retry\nid: {sent_events}\ndata: {{\"job\":{rid},\"events_seen\":{sent_events},\"retry_after_ms\":1000}}\n\n"
    );
    let _ = writer.flush();
    200
}

/// Why one upstream SSE connection ended.
enum StreamEnd {
    /// The terminal `finished` frame was forwarded.
    Finished,
    /// The downstream client went away.
    ClientGone,
    /// Router shutdown requested.
    Shutdown,
    /// Upstream EOF/error (or injected reset) without a terminal frame;
    /// `progress` says whether any new frame made it through first.
    Torn { progress: bool },
}

enum FrameOut {
    Ok,
    Finished,
    ClientGone,
}

/// Forward one complete SSE frame if the client hasn't seen it.
/// `seen` is this frame's 0-based event index on the current
/// connection; the deterministic replay makes it equal to the logical
/// frame index globally, so anything below `sent_events` was already
/// delivered on an earlier connection and is skipped. Comment frames
/// (heartbeats) forward only once the replay has caught up, and the
/// backend's own shutdown notice never forwards — the router decides
/// when this stream ends, not the backend.
fn flush_frame(
    frame: &[String],
    writer: &mut TcpStream,
    from: &str,
    to: &str,
    seen: usize,
    sent_events: &mut usize,
) -> FrameOut {
    if frame[0].starts_with(':') {
        if seen >= *sent_events && !frame[0].starts_with(": shutting down") {
            for l in frame {
                if writer.write_all(l.as_bytes()).is_err() {
                    return FrameOut::ClientGone;
                }
            }
            if writer.write_all(b"\n").is_err() || writer.flush().is_err() {
                return FrameOut::ClientGone;
            }
        }
        return FrameOut::Ok;
    }
    if seen < *sent_events {
        return FrameOut::Ok;
    }
    let finished = frame.iter().any(|l| l.starts_with("event: finished"));
    for l in frame {
        let out = if l.starts_with("data:") { l.replacen(from, to, 1) } else { l.clone() };
        if writer.write_all(out.as_bytes()).is_err() {
            return FrameOut::ClientGone;
        }
    }
    if writer.write_all(b"\n").is_err() || writer.flush().is_err() {
        return FrameOut::ClientGone;
    }
    *sent_events = seen + 1;
    if finished {
        FrameOut::Finished
    } else {
        FrameOut::Ok
    }
}

/// Pump one upstream SSE connection, forwarding only *complete* frames
/// the client hasn't seen. A connection that dies mid-frame never leaks
/// the torn tail downstream: lines buffer into a frame and nothing is
/// written until the blank separator arrives.
fn forward_frames(
    upstream: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    rid: u64,
    remote: u64,
    sent_events: &mut usize,
    abort: &dyn Fn() -> bool,
) -> StreamEnd {
    let from = format!("\"job\":{remote}");
    let to = format!("\"job\":{rid}");
    let start = *sent_events;
    let mut frame: Vec<String> = Vec::new();
    let mut line = String::new();
    let mut seen = 0usize;
    loop {
        if abort() {
            return StreamEnd::Shutdown;
        }
        match crate::chaos::fault("proxy.stream") {
            crate::chaos::Fault::None => {}
            crate::chaos::Fault::Reset => {
                return StreamEnd::Torn { progress: *sent_events > start }
            }
            crate::chaos::Fault::Slow(d) => std::thread::sleep(d),
        }
        match upstream.read_line(&mut line) {
            Ok(0) => return StreamEnd::Torn { progress: *sent_events > start },
            Ok(_) => {
                if !line.ends_with('\n') {
                    // Torn tail at EOF: never forward a partial line.
                    return StreamEnd::Torn { progress: *sent_events > start };
                }
                if line == "\n" || line == "\r\n" {
                    if frame.is_empty() {
                        line.clear();
                        continue;
                    }
                    let is_comment = frame[0].starts_with(':');
                    let outcome = flush_frame(&frame, writer, &from, &to, seen, sent_events);
                    if !is_comment {
                        seen += 1;
                    }
                    frame.clear();
                    match outcome {
                        FrameOut::Ok => {}
                        FrameOut::Finished => return StreamEnd::Finished,
                        FrameOut::ClientGone => return StreamEnd::ClientGone,
                    }
                } else {
                    frame.push(line.clone());
                }
                line.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return StreamEnd::Torn { progress: *sent_events > start },
        }
    }
}

/// Forward the owning backend's SSE stream for router job `rid`,
/// resuming across backend deaths. On reconnect — same backend, or the
/// failover successor re-running the job — the deterministic replay
/// emits the identical logical frame sequence, so already-forwarded
/// frames are skipped by count and the client sees each event exactly
/// once. When the stream is unrecoverable after the head has gone out,
/// the client gets a terminal `retry` hint frame instead of a silent
/// truncation. Returns the status to log.
fn proxy_stream(
    state: &Arc<ClusterState>,
    req: &Request,
    req_id: &str,
    rid: u64,
    writer: &mut TcpStream,
    abort: &dyn Fn() -> bool,
) -> u16 {
    let mut sent_events = 0usize;
    let mut head_sent = false;
    let mut stalls = 0u32;
    loop {
        if abort() {
            if head_sent {
                let _ = writer.write_all(b": shutting down\n\n");
                return 200;
            }
            let _ = Response::error(503, "router shutting down")
                .with_header("x-flexa-request-id", req_id.to_string())
                .write_to(writer, false);
            return 503;
        }
        // Re-resolve the mapping each attempt: a failover (ours or the
        // sweep's) may have moved the job, or degraded it to local.
        let Some((idx, remote)) = lookup(state, rid) else {
            if let Some(job) = lookup_split(state, rid) {
                if head_sent {
                    // Mid-stream degrade: the local job's synthesized
                    // frames don't align with the backend's, so hand the
                    // client a clean resume point instead of guessing.
                    return retry_hint(writer, rid, sent_events);
                }
                let _ = split_stream(&job, req_id, writer, abort);
                return 200;
            }
            if head_sent {
                return retry_hint(writer, rid, sent_events);
            }
            let _ = Response::error(404, &format!("no such job {rid}"))
                .with_header("x-flexa-request-id", req_id.to_string())
                .write_to(writer, false);
            return 404;
        };
        let target = &state.backends[idx];
        let opened = {
            let _span = crate::obs::span_detail("cluster.proxy", &target.spec.id);
            backend::open_stream(
                &target.spec.addr,
                &format!("/v1/jobs/{remote}/events"),
                &passthrough_headers(req, req_id),
                state.config.timeouts(),
            )
        };
        let mut progressed = false;
        match opened {
            Ok((200, _headers, mut upstream)) => {
                if !head_sent {
                    let head = format!(
                        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nx-flexa-request-id: {req_id}\r\nConnection: close\r\n\r\n"
                    );
                    if writer.write_all(head.as_bytes()).is_err() {
                        return 200;
                    }
                    head_sent = true;
                }
                match forward_frames(&mut upstream, writer, rid, remote, &mut sent_events, abort)
                {
                    StreamEnd::Finished => return 200,
                    StreamEnd::ClientGone => return 200,
                    StreamEnd::Shutdown => {
                        let _ = writer.write_all(b": shutting down\n\n");
                        return 200;
                    }
                    StreamEnd::Torn { progress } => progressed = progress,
                }
            }
            Ok((status, _headers, mut upstream)) if !head_sent && stalls == 0 => {
                // First attempt, buffered error from the backend (e.g.
                // 404): pass it through untouched.
                let mut body = String::new();
                let _ = upstream.read_line(&mut body);
                let _ = Response::error(status, body.trim())
                    .with_header("x-flexa-request-id", req_id.to_string())
                    .write_to(writer, false);
                return status;
            }
            _ => {
                state.proxy_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        stalls = if progressed { 0 } else { stalls + 1 };
        if stalls >= 2 {
            // Two fruitless rounds on this mapping: move the job. The
            // loop re-resolves and resumes from the successor's replay.
            failover_job(state, rid);
        }
        if stalls >= 6 {
            if head_sent {
                return retry_hint(writer, rid, sent_events);
            }
            let _ = Response::error(
                502,
                &format!("backend `{}` unreachable and no failover target", target.spec.id),
            )
            .with_header("x-flexa-request-id", req_id.to_string())
            .write_to(writer, false);
            return 502;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Synthesize the SSE stream for a split job from its recorded frames,
/// then poll until the terminal event is written.
fn split_stream(
    job: &SplitJob,
    req_id: &str,
    writer: &mut TcpStream,
    abort: &dyn Fn() -> bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nx-flexa-request-id: {req_id}\r\nConnection: close\r\n\r\n"
    );
    writer.write_all(head.as_bytes())?;
    let mut sent = 0usize;
    loop {
        if abort() {
            writer.write_all(b": shutting down\n\n")?;
            return Ok(());
        }
        let fresh = job.events_from(sent);
        for (name, payload) in &fresh {
            write!(writer, "event: {name}\nid: {sent}\ndata: {payload}\n\n")?;
            sent += 1;
            if name == "finished" {
                writer.flush()?;
                return Ok(());
            }
        }
        writer.flush()?;
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(n: usize) -> Vec<BackendSpec> {
        (0..n)
            .map(|i| BackendSpec { id: format!("b{i}"), addr: format!("127.0.0.1:{}", 7001 + i) })
            .collect()
    }

    #[test]
    fn job_id_rewrite_touches_only_the_leading_field() {
        let body = "{\"job\":42,\"tag\":\"λ\",\"state\":\"finished\",\"x\":[42,42.5]}";
        let out = rewrite_job_id(body, 42, 7);
        assert!(out.starts_with("{\"job\":7,"), "{out}");
        assert!(out.contains("\"x\":[42,42.5]"), "payload 42s must survive: {out}");
    }

    #[test]
    fn placement_key_is_stable_and_lambda_invariant() {
        use crate::api::{ProblemSpec, SolverSpec};
        let state = ClusterState::new(specs(3), ClusterConfig::default());
        let spec = ProblemSpec { rows: 20, cols: 40, ..ProblemSpec::default() };
        let mk = |lambda: Option<f64>| {
            JobSpec::new(
                ProblemSpec { lambda, ..spec.clone() },
                SolverSpec::new("fpa"),
            )
        };
        let k1 = state.placement_key(&mk(Some(0.5)));
        let k2 = state.placement_key(&mk(Some(0.05)));
        let k3 = state.placement_key(&mk(None));
        assert_eq!(k1, k2, "λ-sweep jobs must share a placement key");
        assert_eq!(k1, k3);
        // Memoized: the second call hits the cache (observable as the
        // same key; correctness of memoization is what matters here).
        assert_eq!(state.placement_key(&mk(Some(0.5))), k1);
    }

    #[test]
    fn cluster_state_rejects_nothing_but_routes_404s() {
        let state = Arc::new(ClusterState::new(specs(2), ClusterConfig::default()));
        let req = Request {
            method: "GET".into(),
            path: "/v1/bogus".into(),
            query: Vec::new(),
            headers: Vec::new(),
            body: Vec::new(),
            keep_alive: true,
        };
        match route(&state, &req, "t") {
            ClusterRouted::Response(r) => assert_eq!(r.status, 404),
            _ => panic!("expected a buffered response"),
        }
        let req = Request { method: "PUT".into(), path: "/v1/jobs".into(), ..req };
        match route(&state, &req, "t") {
            ClusterRouted::Response(r) => assert_eq!(r.status, 405),
            _ => panic!("expected a buffered response"),
        }
    }

    #[test]
    fn topology_and_metrics_render_router_families() {
        let state = ClusterState::new(specs(2), ClusterConfig::default());
        state.backends[1].set_draining(true);
        let topo = topology_json(&state, "t");
        assert!(topo.contains("\"id\":\"b0\""), "{topo}");
        assert!(topo.contains("\"draining\":true"), "{topo}");
        assert!(topo.contains("\"alerts\":{\"active\":["), "router alerts embed: {topo}");
        assert!(Json::parse(&topo).is_ok(), "topology stays parseable: {topo}");
        // No backends listening → scrape errors, but router families
        // still render.
        let state = ClusterState::new(
            vec![BackendSpec { id: "dead".into(), addr: "127.0.0.1:1".into() }],
            ClusterConfig {
                connect_timeout: Duration::from_millis(100),
                proxy_timeout: Duration::from_millis(200),
                ..ClusterConfig::default()
            },
        );
        let text = aggregate_metrics(&state, "t");
        assert!(text.contains("flexa_cluster_backends_total 1"), "{text}");
        assert!(text.contains("flexa_cluster_scrape_errors_total 1"), "{text}");
        assert!(text.contains("flexa_cluster_failovers_total 0"), "{text}");
        assert!(text.contains("flexa_cluster_replications_total 0"), "{text}");
        assert!(text.contains("flexa_cluster_replication_errors_total 0"), "{text}");
        assert!(text.contains("flexa_cluster_local_solves_total 0"), "{text}");
        assert!(text.contains("# TYPE flexa_cluster_alerts_total counter"), "{text}");
        assert!(text.contains("flexa_cluster_alerts_total{kind=\"backend-down\"} 0"), "{text}");
        assert!(text.contains("flexa_cluster_alerts_active{kind=\"failover-spike\"} 0"), "{text}");
    }

    /// The watchdog sweep with fabricated clocks: a backend flipping
    /// unhealthy fires `backend-down` (one alert across many sweeps),
    /// recovery resolves it, repeated flips within the window fire
    /// `backend-flapping`, and a failover burst fires `failover-spike`.
    #[test]
    fn watch_sweep_fires_and_resolves_cluster_alerts() {
        use crate::watch::AlertKind;
        let state = ClusterState::new(specs(2), ClusterConfig::default());

        // Healthy fleet: nothing fires.
        watch_sweep(&state, 0.0, 0);
        assert!(state.alerts.active().is_empty());

        // b0 down → backend-down fires once and persists across sweeps.
        for _ in 0..3 {
            state.backends[0].record_probe(false, 3);
        }
        watch_sweep(&state, 1.0, 1_000);
        watch_sweep(&state, 2.0, 2_000);
        assert!(state.alerts.is_firing(AlertKind::BackendDown, "backend:b0"));
        assert_eq!(state.alerts.active().len(), 1, "persisting condition stays one alert");

        // Recovery resolves it.
        state.backends[0].record_probe(true, 3);
        watch_sweep(&state, 3.0, 3_000);
        assert!(!state.alerts.is_firing(AlertKind::BackendDown, "backend:b0"));
        let down = state
            .alerts
            .counts()
            .into_iter()
            .find(|(l, _, _)| *l == "backend-down")
            .unwrap();
        assert_eq!((down.1, down.2), (1, 0), "fired once, none active");

        // Two more down/up cycles push transitions past the flap
        // threshold (3 flips within the 60 s window).
        for _ in 0..2 {
            for _ in 0..3 {
                state.backends[0].record_probe(false, 3);
            }
            state.backends[0].record_probe(true, 3);
        }
        watch_sweep(&state, 4.0, 4_000);
        assert!(state.alerts.is_firing(AlertKind::BackendFlapping, "backend:b0"));
        // Far outside the window the flip rate decays and it resolves.
        watch_sweep(&state, 500.0, 5_000);
        assert!(!state.alerts.is_firing(AlertKind::BackendFlapping, "backend:b0"));

        // A failover burst fires the spike alert; a quiet window clears.
        state.failovers.fetch_add(3, Ordering::Relaxed);
        watch_sweep(&state, 501.0, 6_000);
        assert!(state.alerts.is_firing(AlertKind::FailoverSpike, "cluster"));
        watch_sweep(&state, 1000.0, 7_000);
        assert!(!state.alerts.is_firing(AlertKind::FailoverSpike, "cluster"));
    }

    #[test]
    fn submit_degrades_to_a_router_local_solve_when_nothing_is_placeable() {
        let _chaos = crate::chaos::scoped_off();
        let config = ClusterConfig {
            connect_timeout: Duration::from_millis(100),
            proxy_timeout: Duration::from_millis(200),
            ..ClusterConfig::default()
        };
        let state = Arc::new(ClusterState::new(
            vec![BackendSpec { id: "dead".into(), addr: "127.0.0.1:1".into() }],
            config,
        ));
        let req = Request {
            method: "POST".into(),
            path: "/v1/jobs".into(),
            query: Vec::new(),
            headers: Vec::new(),
            body: br#"{"problem":"lasso","rows":10,"cols":20,"seed":3,"algo":"fpa","max_iters":5,"warm_start":false,"tag":"deg"}"#.to_vec(),
            keep_alive: true,
        };
        let resp = submit(&state, &req, "t");
        let body = String::from_utf8_lossy(&resp.body).to_string();
        assert_eq!(resp.status, 202, "{body}");
        assert!(body.contains("\"backend\":\"router-local\""), "{body}");
        let rid = Json::parse(&body).unwrap().get("job").and_then(Json::as_f64).unwrap() as u64;
        let job = lookup_split(&state, rid).expect("degraded to a local job");
        for _ in 0..600 {
            if job.finished() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(job.finished(), "local solve must finish");
        let status = job.status();
        assert_eq!(status.solver, "local/fpa");
        assert!(matches!(status.outcome, Some(JobOutcome::Done { .. })));
        assert_eq!(state.local_solves.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_entries_rerender_bit_exact() {
        let entry = Json::parse(
            "{\"key\":\"18446744073709551615\",\"x\":[0.1,-2.5e-3,3],\"tau\":0.5}",
        )
        .unwrap();
        let out = render_snapshot_entry(&entry);
        let back = Json::parse(&out).unwrap();
        assert_eq!(back.get("key").and_then(Json::as_str), Some("18446744073709551615"));
        let Some(Json::Arr(xs)) = back.get("x") else { panic!("x survives") };
        assert_eq!(xs[0].as_f64().unwrap().to_bits(), 0.1f64.to_bits());
        assert_eq!(back.get("tau").and_then(Json::as_f64), Some(0.5));
        assert!(back.get("lipschitz").is_none(), "absent fields stay absent");
    }
}
