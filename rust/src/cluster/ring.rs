//! Consistent-hash ring mapping warm-start fingerprints to backends.
//!
//! Each backend contributes `replicas` virtual points, hashed from
//! `"{id}/{replica}"` with the same FNV-1a the warm-start cache keys use.
//! A key is placed on the first point clockwise from the key's hash
//! whose backend is eligible (healthy, not draining). Because a
//! backend's points depend only on its own id, removing one backend
//! remaps *only the keys that lived on it* — every other key keeps its
//! placement, which is exactly the property that keeps λ-sweep cache
//! affinity intact across membership changes (pinned by the property
//! tests below).

use crate::serve::cache::Fnv;

/// Hash of one virtual point: FNV-1a over `"{id}/{replica}"`.
fn point_hash(id: &str, replica: usize) -> u64 {
    let mut h = Fnv::new();
    h.write(id.as_bytes());
    h.write(b"/");
    h.write(&(replica as u64).to_le_bytes());
    h.finish()
}

/// The ring: sorted virtual points, each owned by a backend index.
#[derive(Clone, Debug)]
pub struct Ring {
    /// `(point hash, backend index)`, sorted by hash.
    points: Vec<(u64, usize)>,
    backends: usize,
}

impl Ring {
    /// Build from backend ids (indices into the caller's backend list).
    /// `replicas` virtual points per backend smooth the key shares; 64
    /// keeps the max/min share ratio near 1.3 for small clusters.
    pub fn build(ids: &[String], replicas: usize) -> Self {
        let replicas = replicas.max(1);
        let mut points = Vec::with_capacity(ids.len() * replicas);
        for (idx, id) in ids.iter().enumerate() {
            for r in 0..replicas {
                points.push((point_hash(id, r), idx));
            }
        }
        // Ties (hash collisions across ids) resolve by backend index so
        // the walk order is deterministic regardless of insertion order.
        points.sort_unstable();
        Self { points, backends: ids.len() }
    }

    /// Number of backends the ring was built over.
    pub fn backends(&self) -> usize {
        self.backends
    }

    /// Place `key` on the first eligible backend clockwise from the
    /// key's position. `None` when no backend is eligible.
    pub fn place(&self, key: u64, eligible: impl Fn(usize) -> bool) -> Option<usize> {
        for &idx in self.order(key).iter() {
            if eligible(idx) {
                return Some(idx);
            }
        }
        None
    }

    /// Distinct backends in successor order from `key`'s ring position —
    /// element 0 is the primary owner, element 1 the first hand-off
    /// target on drain, and so on.
    pub fn order(&self, key: u64) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.backends);
        if self.points.is_empty() {
            return out;
        }
        let start = self.points.partition_point(|&(h, _)| h < key);
        let n = self.points.len();
        for i in 0..n {
            let (_, idx) = self.points[(start + i) % n];
            if !out.contains(&idx) {
                out.push(idx);
                if out.len() == self.backends {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    /// Deterministic sample keys (no RNG in tests: placement must be a
    /// pure function of the key anyway).
    fn sample_keys(n: usize) -> Vec<u64> {
        (0..n as u64)
            .map(|i| {
                let mut h = Fnv::new();
                h.write(&i.to_le_bytes());
                h.finish()
            })
            .collect()
    }

    /// Placement is a pure function of (membership, key): rebuilding the
    /// ring — as a restarted router does — reproduces every placement.
    #[test]
    fn placement_is_deterministic_across_rebuilds() {
        let names = ids(&["a", "b", "c", "d", "e"]);
        let r1 = Ring::build(&names, 64);
        let r2 = Ring::build(&names, 64);
        for key in sample_keys(512) {
            assert_eq!(r1.place(key, |_| true), r2.place(key, |_| true));
            assert_eq!(r1.order(key), r2.order(key));
        }
    }

    /// The consistency property the cluster depends on: removing one
    /// backend remaps only that backend's keys (everything else stays
    /// put), and the remapped share is close to the removed backend's
    /// fair share of the keyspace.
    #[test]
    fn removing_one_backend_remaps_only_its_keys() {
        let all = ids(&["a", "b", "c", "d", "e"]);
        let without_c: Vec<String> =
            all.iter().filter(|s| *s != "c").cloned().collect();
        let full = Ring::build(&all, 64);
        let reduced = Ring::build(&without_c, 64);
        let keys = sample_keys(4000);

        let removed = 2; // index of "c" in `all`
        let mut moved = 0usize;
        let mut on_removed = 0usize;
        for &key in &keys {
            let before = full.place(key, |_| true).unwrap();
            let after_names =
                reduced.place(key, |_| true).map(|i| without_c[i].clone()).unwrap();
            if before == removed {
                on_removed += 1;
                // Keys from the removed backend land on its ring
                // successor — the same backend an eligibility filter
                // (drain) would pick on the full ring.
                let successor = full.place(key, |i| i != removed).unwrap();
                assert_eq!(after_names, all[successor], "key {key:#x}");
            } else {
                // Every other key keeps its backend.
                assert_eq!(after_names, all[before], "key {key:#x} moved needlessly");
            }
            if all[before] != after_names {
                moved += 1;
            }
        }
        assert_eq!(moved, on_removed, "only the removed backend's keys move");
        // Fair share is 1/5 of the keys; virtual nodes keep the actual
        // share within a factor-2 slack band.
        let share = on_removed as f64 / keys.len() as f64;
        assert!(
            share > 0.5 / all.len() as f64 && share < 2.0 / all.len() as f64,
            "removed backend held {share:.3} of the keyspace"
        );
    }

    /// All backends get a non-trivial share of the keyspace.
    #[test]
    fn shares_are_roughly_balanced() {
        let names = ids(&["a", "b", "c", "d"]);
        let ring = Ring::build(&names, 64);
        let keys = sample_keys(4000);
        let mut counts = vec![0usize; names.len()];
        for &key in &keys {
            counts[ring.place(key, |_| true).unwrap()] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let share = c as f64 / keys.len() as f64;
            assert!(
                share > 0.5 / names.len() as f64 && share < 2.0 / names.len() as f64,
                "backend {i} holds {share:.3}"
            );
        }
    }

    /// `place` with an eligibility filter walks successors: draining or
    /// unhealthy backends are skipped, and with nothing eligible the
    /// placement is `None`.
    #[test]
    fn eligibility_filter_walks_successors() {
        let names = ids(&["a", "b", "c"]);
        let ring = Ring::build(&names, 32);
        for key in sample_keys(64) {
            let order = ring.order(key);
            assert_eq!(order.len(), 3);
            let primary = order[0];
            assert_eq!(ring.place(key, |_| true), Some(primary));
            assert_eq!(ring.place(key, |i| i != primary), Some(order[1]));
            assert_eq!(ring.place(key, |_| false), None);
        }
    }
}
