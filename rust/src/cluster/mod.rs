//! `flexa::cluster` — a std-only router/coordinator in front of N
//! `flexa serve --http` backends.
//!
//! ```text
//!                         ┌────────────────────────┐
//!   clients ── HTTP ────▶ │  flexa cluster (router) │
//!                         │  ring ▪ health ▪ split  │
//!                         └───┬─────────┬─────────┬─┘
//!                             ▼         ▼         ▼
//!                         backend a  backend b  backend c
//!                         (serve --http, warm-start caches)
//! ```
//!
//! The router owns no solver state. It places `POST /v1/jobs` on a
//! consistent-hash [`ring::Ring`] keyed by the job's *warm-start
//! fingerprint* — the same λ-excluded FNV-1a key the backend cache
//! uses — so every λ of a regularization-path sweep lands on the node
//! that already holds the sweep's cached iterate. Job status, SSE event
//! streams and cancellation proxy to the owning backend with the
//! router's job id substituted for the backend's.
//!
//! [`health`] probes `/healthz` on a cadence and stops placing on a
//! backend after a consecutive-failure threshold; a drain
//! (`POST /v1/cluster/backends/{id}/drain`) additionally hands the
//! backend's warm-start snapshot to its ring successors so sweeps
//! continue warm elsewhere. `/metrics` sums every backend's series and
//! appends router-level `flexa_cluster_*` families.
//!
//! Jobs above a size threshold take the [`split`] path instead of
//! placement: the router runs the outer ADMM consensus loop from the
//! paper's block-splitting formulation, backends solve the per-block
//! subproblems as ordinary `admm-step` jobs on full replicated state,
//! and the merged trajectory is bit-identical to a single-node
//! [`crate::algos::admm::Admm`] run (§"bit-exact split" in the tests).
//!
//! The cluster is crash-tolerant: warm-start writes replicate
//! asynchronously to each key's ring successor, proxied jobs carry
//! enough state (body, identity, idempotency key) to re-dispatch to
//! that successor when their backend dies, SSE streams resume across
//! the failover at frame granularity (deterministic re-runs replay the
//! identical sequence, so the client sees each event exactly once), and
//! with every backend down the router solves registry-spec jobs itself.
//! `tests/chaos.rs` drives all of it under seeded fault injection
//! ([`crate::chaos`]) and pins failover results bit-identical to the
//! fault-free golden runs.

pub mod backend;
pub mod health;
pub mod ring;
pub mod router;
pub mod split;

pub use backend::{parse_backend_arg, parse_backends_file, BackendSpec, Timeouts};
pub use health::{BackendState, HealthConfig};
pub use ring::Ring;
pub use router::{ClusterConfig, ClusterServer, ClusterState, SpawnedCluster};
pub use split::{SplitConfig, SplitJob, SplitPlan};
