//! Per-job phase profiles: where one job's wall-clock time went.
//!
//! The scheduler owns a [`ProfileStore`] and stamps it at every
//! lifecycle edge: enqueue, first start, cache probe, each iteration,
//! kernel-time flush, retry, terminal. `GET /v1/jobs/{id}/profile`
//! serves the resulting breakdown — the per-job complement to the
//! aggregate `/metrics` histograms. Finished profiles are pruned in
//! completion order under the same retention count as job results, so
//! the map is bounded under churn.

use crate::serve::jobfile::esc;
use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Iteration timing summary (microseconds).
#[derive(Clone, Debug, Default)]
pub struct IterStats {
    pub count: u64,
    pub total_us: u64,
    pub max_us: u64,
}

/// How many `(iteration, threads)` share changes are kept per job;
/// rebalance churn past this is dropped (count, not crash).
pub const MAX_SHARE_CHANGES: usize = 64;

/// One job's phase breakdown, built incrementally over its lifetime.
#[derive(Clone, Debug)]
pub struct JobProfile {
    pub job: u64,
    pub tenant: String,
    pub solver: String,
    /// Lifecycle: "queued" → "running" → the terminal outcome label.
    pub state: String,
    pub retries: u64,
    pub enqueued_us: u64,
    /// First `Started` (0 until the job runs).
    pub started_us: u64,
    pub finished_us: u64,
    /// Enqueue → first start.
    pub queue_us: u64,
    pub cache_probe_us: u64,
    /// None until a probe happens (e.g. solver without warm-start).
    pub cache_hit: Option<bool>,
    /// Worker-held time, accumulated across retry attempts.
    pub service_us: u64,
    /// Parallel-kernel region time on the solve thread.
    pub kernel_us: u64,
    pub iterations: IterStats,
    /// `(iteration, threads)` at each core-budget change (first entry
    /// is the initial share), capped at [`MAX_SHARE_CHANGES`].
    pub thread_shares: Vec<(u64, usize)>,
    /// Enqueue → terminal (0 until terminal).
    pub total_us: u64,
}

impl JobProfile {
    fn new(job: u64, tenant: &str, enqueued_us: u64) -> Self {
        JobProfile {
            job,
            tenant: tenant.to_string(),
            solver: String::new(),
            state: "queued".to_string(),
            retries: 0,
            enqueued_us,
            started_us: 0,
            finished_us: 0,
            queue_us: 0,
            cache_probe_us: 0,
            cache_hit: None,
            service_us: 0,
            kernel_us: 0,
            iterations: IterStats::default(),
            thread_shares: Vec::new(),
            total_us: 0,
        }
    }

    /// Record one iteration and the thread share it ran under.
    pub fn add_iteration(&mut self, dur_us: u64, threads: usize) {
        let iter = self.iterations.count;
        self.iterations.count += 1;
        self.iterations.total_us = self.iterations.total_us.saturating_add(dur_us);
        self.iterations.max_us = self.iterations.max_us.max(dur_us);
        match self.thread_shares.last() {
            Some(&(_, last)) if last == threads => {}
            _ if self.thread_shares.len() >= MAX_SHARE_CHANGES => {}
            _ => self.thread_shares.push((iter, threads)),
        }
    }

    /// Render the profile as the `/v1/jobs/{id}/profile` JSON body.
    pub fn json(&self) -> String {
        let ms = |us: u64| us as f64 / 1_000.0;
        let mean_us = if self.iterations.count == 0 {
            0.0
        } else {
            self.iterations.total_us as f64 / self.iterations.count as f64
        };
        let mut shares = String::new();
        for (i, (iter, threads)) in self.thread_shares.iter().enumerate() {
            if i > 0 {
                shares.push(',');
            }
            shares.push_str(&format!("{{\"iteration\":{iter},\"threads\":{threads}}}"));
        }
        format!(
            concat!(
                "{{\"job\":{},\"tenant\":\"{}\",\"solver\":\"{}\",\"state\":\"{}\",",
                "\"retries\":{},\"queue_ms\":{:.3},\"cache_probe_ms\":{:.3},\"cache_hit\":{},",
                "\"service_ms\":{:.3},\"kernel_ms\":{:.3},",
                "\"iterations\":{{\"count\":{},\"total_ms\":{:.3},\"mean_ms\":{:.3},\"max_ms\":{:.3}}},",
                "\"thread_shares\":[{}],\"total_ms\":{:.3}}}"
            ),
            self.job,
            esc(&self.tenant),
            esc(&self.solver),
            esc(&self.state),
            self.retries,
            ms(self.queue_us),
            ms(self.cache_probe_us),
            match self.cache_hit {
                None => "null".to_string(),
                Some(hit) => hit.to_string(),
            },
            ms(self.service_us),
            ms(self.kernel_us),
            self.iterations.count,
            ms(self.iterations.total_us),
            mean_us / 1_000.0,
            ms(self.iterations.max_us),
            shares,
            ms(self.total_us),
        )
    }
}

struct Inner {
    map: HashMap<u64, JobProfile>,
    finished_order: VecDeque<u64>,
    retention: usize,
}

/// Scheduler-owned store of job profiles, bounded by retaining only
/// the last `retention` *finished* jobs (live jobs are never evicted).
pub struct ProfileStore {
    inner: Mutex<Inner>,
}

impl ProfileStore {
    pub fn new(retention: usize) -> Self {
        ProfileStore {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                finished_order: VecDeque::new(),
                retention,
            }),
        }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Create the profile at enqueue time.
    pub fn enqueued(&self, job: u64, tenant: &str, enqueued_us: u64) {
        let mut inner = self.locked();
        inner.map.entry(job).or_insert_with(|| JobProfile::new(job, tenant, enqueued_us));
    }

    /// Mutate a live profile in place (no-op for unknown/pruned jobs).
    pub fn with<F: FnOnce(&mut JobProfile)>(&self, job: u64, f: F) {
        let mut inner = self.locked();
        if let Some(p) = inner.map.get_mut(&job) {
            f(p);
        }
    }

    /// Mark terminal, stamp totals, and prune past retention.
    pub fn terminal(&self, job: u64, state: &str, now_us: u64) {
        let mut inner = self.locked();
        if let Some(p) = inner.map.get_mut(&job) {
            p.state = state.to_string();
            p.finished_us = now_us;
            p.total_us = now_us.saturating_sub(p.enqueued_us);
            inner.finished_order.push_back(job);
        }
        while inner.finished_order.len() > inner.retention {
            if let Some(old) = inner.finished_order.pop_front() {
                inner.map.remove(&old);
            }
        }
    }

    /// Clone one job's profile.
    pub fn get(&self, job: u64) -> Option<JobProfile> {
        self.locked().map.get(&job).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::jobfile::Json;

    #[test]
    fn lifecycle_stamps_and_json_round_trip() {
        let store = ProfileStore::new(4);
        store.enqueued(1, "acme", 1_000);
        store.with(1, |p| {
            p.state = "running".into();
            p.started_us = 3_000;
            p.queue_us = 2_000;
            p.solver = "fista".into();
            p.cache_probe_us = 150;
            p.cache_hit = Some(true);
            p.service_us = 9_000;
            p.kernel_us = 7_000;
            p.add_iteration(400, 4);
            p.add_iteration(600, 4);
            p.add_iteration(500, 2);
        });
        store.terminal(1, "finished", 12_500);
        let p = store.get(1).expect("profile retained");
        assert_eq!(p.total_us, 11_500);
        assert_eq!(p.iterations.count, 3);
        assert_eq!(p.iterations.max_us, 600);
        // Share changes dedupe runs of equal thread counts.
        assert_eq!(p.thread_shares, vec![(0, 4), (2, 2)]);
        let parsed = Json::parse(&p.json()).expect("profile JSON must parse");
        assert_eq!(parsed.get("job").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("state").and_then(Json::as_str), Some("finished"));
        assert_eq!(parsed.get("cache_hit").and_then(Json::as_bool), Some(true));
        assert_eq!(parsed.get("queue_ms").and_then(Json::as_f64), Some(2.0));
        let iters = parsed.get("iterations").expect("iterations object");
        assert_eq!(iters.get("count").and_then(Json::as_f64), Some(3.0));
        // queue + service account for the job's life up to bookkeeping
        // slack (terminal stamp minus start+service).
        assert!(p.queue_us + p.service_us <= p.total_us);
    }

    #[test]
    fn retention_prunes_only_finished_jobs() {
        let store = ProfileStore::new(2);
        for id in 1..=5u64 {
            store.enqueued(id, "t", id * 100);
        }
        for id in 1..=4u64 {
            store.terminal(id, "finished", 10_000 + id);
        }
        assert!(store.get(1).is_none(), "oldest finished pruned");
        assert!(store.get(2).is_none());
        assert!(store.get(3).is_some());
        assert!(store.get(4).is_some());
        assert!(store.get(5).is_some(), "live job survives churn");
        // cache_hit renders as JSON null until a probe happens.
        let body = store.get(5).unwrap().json();
        assert!(body.contains("\"cache_hit\":null"));
        assert!(Json::parse(&body).is_ok());
    }
}
