//! Bounded per-thread span rings with a global registry.
//!
//! Each recording thread claims a ring from a process-wide registry
//! (or allocates one, up to [`MAX_RINGS`]) and keeps it in a
//! thread-local handle; when the thread exits, the handle's drop
//! releases the claim but *keeps the contents*, so spans from
//! short-lived connection threads stay exportable and the next thread
//! reuses the slot instead of growing the registry forever.
//!
//! The hot path never blocks: `record` uses `try_lock` (the only
//! contender is a trace export) and bumps a relaxed atomic drop
//! counter — surfaced as `flexa_obs_spans_dropped_total` — when the
//! ring is contended, the registry is full, or an old span is
//! overwritten. Dropping telemetry under pressure is the contract;
//! stalling a solve for it is not.

use super::span::Span;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Spans retained per ring before overwriting the oldest.
pub const RING_CAPACITY: usize = 4096;

/// Registry size cap: beyond this many simultaneous recording
/// threads, extra threads drop their spans (counted) rather than grow.
pub const MAX_RINGS: usize = 256;

struct Ring {
    /// Circular once `spans.len() == RING_CAPACITY`; grown lazily so
    /// idle threads cost nothing.
    spans: Vec<Span>,
    /// Next write index once circular.
    next: usize,
}

struct Handle {
    ring: Mutex<Ring>,
    in_use: AtomicBool,
}

static REGISTRY: OnceLock<Mutex<Vec<Arc<Handle>>>> = OnceLock::new();
static DROPPED: AtomicU64 = AtomicU64::new(0);
static RECORDED: AtomicU64 = AtomicU64::new(0);

fn registry() -> &'static Mutex<Vec<Arc<Handle>>> {
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Thread-local claim on a ring slot; releases (but does not clear)
/// the slot when the thread exits.
struct LocalRing(RefCell<Option<(usize, Arc<Handle>)>>);

impl Drop for LocalRing {
    fn drop(&mut self) {
        if let Some((_, handle)) = self.0.borrow_mut().take() {
            handle.in_use.store(false, Ordering::Release);
        }
    }
}

thread_local! {
    static LOCAL: LocalRing = LocalRing(RefCell::new(None));
}

/// Claim a released slot (keeping its old spans) or allocate a new one.
fn claim() -> Option<(usize, Arc<Handle>)> {
    let mut reg = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    for (i, handle) in reg.iter().enumerate() {
        if handle
            .in_use
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            return Some((i, Arc::clone(handle)));
        }
    }
    if reg.len() >= MAX_RINGS {
        return None;
    }
    let handle = Arc::new(Handle {
        ring: Mutex::new(Ring { spans: Vec::new(), next: 0 }),
        in_use: AtomicBool::new(true),
    });
    reg.push(Arc::clone(&handle));
    Some((reg.len() - 1, handle))
}

/// Record one span into the calling thread's ring. Never blocks;
/// drops (counted) under contention or exhaustion.
pub fn record(span: Span) {
    LOCAL.with(|local| {
        let mut slot = local.0.borrow_mut();
        if slot.is_none() {
            *slot = claim();
        }
        let Some((_, handle)) = slot.as_ref() else {
            DROPPED.fetch_add(1, Ordering::Relaxed);
            return;
        };
        match handle.ring.try_lock() {
            Ok(mut ring) => {
                if ring.spans.len() < RING_CAPACITY {
                    ring.spans.push(span);
                } else {
                    // Overwriting loses the oldest span: count it so
                    // the drop counter reflects every loss.
                    let next = ring.next;
                    ring.spans[next] = span;
                    ring.next = (next + 1) % RING_CAPACITY;
                    DROPPED.fetch_add(1, Ordering::Relaxed);
                }
                RECORDED.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                DROPPED.fetch_add(1, Ordering::Relaxed);
            }
        }
    });
}

/// Total spans lost to contention, registry exhaustion, or overwrite.
pub fn spans_dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Total spans successfully stored (including ones later overwritten).
/// `recorded + dropped` is every `record` attempt ever made, so the
/// loss *rate* — not just the loss count — is observable from
/// `/metrics`.
pub fn spans_recorded() -> u64 {
    RECORDED.load(Ordering::Relaxed)
}

/// Spans currently buffered per ring: `(ring_index, occupancy)`.
/// Occupancy saturates at [`RING_CAPACITY`]; a full ring means new
/// spans are overwriting old ones on that thread.
pub fn ring_occupancy() -> Vec<(usize, usize)> {
    let handles: Vec<(usize, Arc<Handle>)> = {
        let reg = match registry().lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        reg.iter().enumerate().map(|(i, h)| (i, Arc::clone(h))).collect()
    };
    handles
        .into_iter()
        .map(|(i, handle)| {
            let len = match handle.ring.lock() {
                Ok(g) => g.spans.len(),
                Err(p) => p.into_inner().spans.len(),
            };
            (i, len)
        })
        .collect()
}

/// Snapshot every ring (without clearing), keeping spans that *end* at
/// or after `since_us`. Returns `(ring_index, span)` pairs sorted by
/// start time; the ring index becomes the trace `tid`.
pub fn snapshot(since_us: u64) -> Vec<(u32, Span)> {
    let handles: Vec<(usize, Arc<Handle>)> = {
        let reg = match registry().lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        reg.iter().enumerate().map(|(i, h)| (i, Arc::clone(h))).collect()
    };
    let mut out = Vec::new();
    for (i, handle) in handles {
        let ring = match handle.ring.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        for span in ring.spans.iter() {
            if span.start_us.saturating_add(span.dur_us) >= since_us {
                out.push((i as u32, *span));
            }
        }
    }
    out.sort_by_key(|(_, s)| s.start_us);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::InlineStr;

    fn mk(phase: &'static str, start_us: u64, dur_us: u64, job: u64) -> Span {
        Span {
            phase,
            start_us,
            dur_us,
            job,
            tenant: InlineStr::new("t"),
            request_id: InlineStr::EMPTY,
            detail: InlineStr::EMPTY,
        }
    }

    #[test]
    fn recorded_spans_appear_in_snapshot_sorted() {
        record(mk("test.b", 2_000, 10, 1));
        record(mk("test.a", 1_000, 10, 2));
        let snap = snapshot(0);
        let test_spans: Vec<&Span> =
            snap.iter().map(|(_, s)| s).filter(|s| s.phase.starts_with("test.")).collect();
        assert!(test_spans.len() >= 2);
        let mut last = 0;
        for s in &test_spans {
            assert!(s.start_us >= last, "snapshot must be start-sorted");
            last = s.start_us;
        }
    }

    #[test]
    fn since_filter_keeps_spans_ending_after_cutoff() {
        record(mk("cutoff.old", 10, 5, 3));
        record(mk("cutoff.spanning", 90, 30, 3));
        record(mk("cutoff.new", 200, 5, 3));
        let snap = snapshot(100);
        let phases: Vec<&str> =
            snap.iter().map(|(_, s)| s.phase).filter(|p| p.starts_with("cutoff.")).collect();
        assert!(!phases.contains(&"cutoff.old"));
        assert!(phases.contains(&"cutoff.spanning"), "span straddling the cutoff is kept");
        assert!(phases.contains(&"cutoff.new"));
    }

    #[test]
    fn overflow_overwrites_and_counts_drops() {
        let before = spans_dropped();
        let recorded_before = spans_recorded();
        for i in 0..(RING_CAPACITY as u64 + 8) {
            record(mk("flood.x", i, 1, 9));
        }
        assert!(spans_dropped() > before, "overwrites must bump the drop counter");
        assert!(
            spans_recorded() >= recorded_before + RING_CAPACITY as u64,
            "every stored span must bump the recorded counter"
        );
        let flood =
            snapshot(0).into_iter().filter(|(_, s)| s.phase == "flood.x").count();
        assert!(flood <= RING_CAPACITY);
    }

    #[test]
    fn occupancy_reports_this_threads_ring() {
        record(mk("occ.x", 1, 1, 4));
        let occ = ring_occupancy();
        assert!(!occ.is_empty(), "at least the recording thread's ring is listed");
        assert!(occ.iter().all(|(_, n)| *n <= RING_CAPACITY));
        assert!(occ.iter().any(|(_, n)| *n > 0), "this thread's ring holds the span");
    }
}
