//! `flexa::obs` — always-on, bounded-cost observability.
//!
//! Three layers, front to back:
//!
//! - **Spans** ([`span`]): phase-labeled monotonic-clock intervals
//!   (`http.parse`, `queue.wait`, `cache.probe`, `solve.iter`,
//!   `kernel`, `sse.emit`, `retry.backoff`, `cluster.proxy`,
//!   `split.outer`) carrying job id, tenant, and the
//!   `x-flexa-request-id` the cluster router propagates to backends so
//!   one trace stitches across nodes. Spans land in per-thread ring
//!   buffers ([`ring`]) and export as Chrome trace-event JSON
//!   ([`trace`]) via `GET /v1/debug/trace` and `flexa trace`.
//! - **Histograms** ([`ObsMetrics`]): production latency distributions
//!   promoted from `bench::Histogram` into `/metrics` as real
//!   Prometheus `histogram` families, so the load-bench SLO quantities
//!   (queue/service/iteration/request latency) are observable live.
//! - **Profiles** ([`profile`]): per-job phase breakdowns served by
//!   `GET /v1/jobs/{id}/profile`.
//!
//! The hot-path contract everywhere: no allocation, no blocking, no
//! effect on solver arithmetic. Telemetry under pressure is *dropped
//! and counted* (`flexa_obs_spans_dropped_total`), never waited on —
//! solver bit-identity and golden IterEvent streams are untouched
//! because observation only ever reads clocks around compute, never
//! reorders it.

pub mod profile;
pub mod ring;
pub mod span;
pub mod trace;

pub use profile::{JobProfile, ProfileStore};
pub use ring::{ring_occupancy, snapshot, spans_dropped, spans_recorded};
pub use span::{
    add_kernel_us, ctx, ctx_guard, init, instant_us, now_us, record, reset_kernel_us, set_ctx,
    span, span_detail, take_kernel_us, Ctx, InlineStr, Span, SpanGuard,
};

use crate::bench::histogram::{Histogram, BUCKET_BOUNDS_US};
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Process-wide latency histogram families, rendered into `/metrics`.
///
/// Global rather than per-server: an in-process test may run several
/// servers whose recordings share these families, so assertions must
/// check "nonzero and parseable", never exact counts. Fixed bucket
/// bounds (the `bench::Histogram` 1–2–5 series) keep sample lines
/// textually identical across backends, which is what lets the cluster
/// router's `/metrics` aggregation sum them line-by-line.
pub struct ObsMetrics {
    /// Request duration by endpoint label.
    http: Mutex<BTreeMap<&'static str, Histogram>>,
    /// Enqueue → first start.
    job_queue: Mutex<Histogram>,
    /// Worker-held time per attempt.
    job_service: Mutex<Histogram>,
    /// Iteration duration by solver name.
    job_iteration: Mutex<BTreeMap<String, Histogram>>,
}

static METRICS: OnceLock<ObsMetrics> = OnceLock::new();

/// The process-wide metrics instance.
pub fn metrics() -> &'static ObsMetrics {
    METRICS.get_or_init(|| ObsMetrics {
        http: Mutex::new(BTreeMap::new()),
        job_queue: Mutex::new(Histogram::new()),
        job_service: Mutex::new(Histogram::new()),
        job_iteration: Mutex::new(BTreeMap::new()),
    })
}

fn locked<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

impl ObsMetrics {
    pub fn record_http(&self, endpoint: &'static str, us: u64) {
        locked(&self.http).entry(endpoint).or_default().record_us(us);
    }

    pub fn record_queue(&self, us: u64) {
        locked(&self.job_queue).record_us(us);
    }

    pub fn record_service(&self, us: u64) {
        locked(&self.job_service).record_us(us);
    }

    /// `(good, total)` service-time counts at the largest histogram
    /// bucket bound ≤ `threshold_us`. Bucket granularity means the good
    /// count can only *undercount* jobs within the threshold, so SLO
    /// attainment computed from it is conservative (pessimistic), never
    /// flattering.
    pub fn service_under(&self, threshold_us: u64) -> (u64, u64) {
        let h = locked(&self.job_service);
        let mut good = 0u64;
        for (bound, cumulative) in h.cumulative_buckets() {
            match bound {
                Some(us) if us <= threshold_us => good = cumulative,
                _ => break,
            }
        }
        (good, h.count())
    }

    pub fn record_iteration(&self, solver: &str, us: u64) {
        let mut map = locked(&self.job_iteration);
        match map.get_mut(solver) {
            Some(h) => h.record_us(us),
            None => {
                let mut h = Histogram::new();
                h.record_us(us);
                map.insert(solver.to_string(), h);
            }
        }
    }

    /// Append every histogram family (plus the span drop counter) in
    /// Prometheus text format.
    pub fn render_into(&self, out: &mut String) {
        let http = locked(&self.http);
        render_family(
            out,
            "flexa_http_request_duration_seconds",
            "HTTP request duration by endpoint",
            "endpoint",
            http.iter().map(|(k, h)| (*k, h)),
        );
        drop(http);
        render_family(
            out,
            "flexa_job_queue_seconds",
            "Job time from enqueue to first start",
            "",
            std::iter::once(("", &*locked(&self.job_queue))),
        );
        render_family(
            out,
            "flexa_job_service_seconds",
            "Job worker-held time per attempt",
            "",
            std::iter::once(("", &*locked(&self.job_service))),
        );
        let iter = locked(&self.job_iteration);
        render_family(
            out,
            "flexa_job_iteration_seconds",
            "Solver iteration duration by solver",
            "solver",
            iter.iter().map(|(k, h)| (k.as_str(), h)),
        );
        drop(iter);
        out.push_str(
            "# HELP flexa_obs_spans_dropped_total Trace spans lost to ring contention, registry exhaustion, or overwrite\n",
        );
        out.push_str("# TYPE flexa_obs_spans_dropped_total counter\n");
        out.push_str(&format!("flexa_obs_spans_dropped_total {}\n", ring::spans_dropped()));
        out.push_str(
            "# HELP flexa_obs_spans_recorded_total Trace spans successfully stored in ring buffers\n",
        );
        out.push_str("# TYPE flexa_obs_spans_recorded_total counter\n");
        out.push_str(&format!("flexa_obs_spans_recorded_total {}\n", ring::spans_recorded()));
        out.push_str("# HELP flexa_obs_ring_spans Spans currently buffered per span ring\n");
        out.push_str("# TYPE flexa_obs_ring_spans gauge\n");
        for (idx, occupancy) in ring::ring_occupancy() {
            out.push_str(&format!("flexa_obs_ring_spans{{ring=\"{idx}\"}} {occupancy}\n"));
        }
    }
}

/// Minimal Prometheus label-value escape (backslash, quote, newline).
fn esc_label(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            _ => s.push(c),
        }
    }
    s
}

/// Render one histogram family. `label_key` empty means unlabeled (the
/// iterator then yields exactly one `("", h)` pair). Every bucket bound
/// is emitted even at count 0 so the le-series is identical on every
/// node — the cluster aggregator sums sample lines textually and
/// mismatched series would corrupt cumulative counts.
fn render_family<'a>(
    out: &mut String,
    name: &str,
    help: &str,
    label_key: &str,
    series: impl Iterator<Item = (&'a str, &'a Histogram)>,
) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for (label_val, h) in series {
        let prefix = if label_key.is_empty() {
            String::new()
        } else {
            format!("{label_key}=\"{}\",", esc_label(label_val))
        };
        for (bound, cumulative) in h.cumulative_buckets() {
            let le = match bound {
                Some(us) => format!("{}", us as f64 / 1e6),
                None => "+Inf".to_string(),
            };
            out.push_str(&format!("{name}_bucket{{{prefix}le=\"{le}\"}} {cumulative}\n"));
        }
        let plain = if label_key.is_empty() {
            String::new()
        } else {
            format!("{{{label_key}=\"{}\"}}", esc_label(label_val))
        };
        out.push_str(&format!("{name}_sum{plain} {}\n", h.sum_us() as f64 / 1e6));
        out.push_str(&format!("{name}_count{plain} {}\n", h.count()));
    }
}

/// Every bucket bound in the family series, for tests and docs.
pub fn bucket_bounds_us() -> &'static [u64] {
    BUCKET_BOUNDS_US
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendered_families_have_cumulative_le_ordered_buckets() {
        let m = metrics();
        m.record_http("post_jobs", 150);
        m.record_http("post_jobs", 3_000);
        m.record_queue(700);
        m.record_service(42_000);
        m.record_iteration("fista", 900);
        let mut out = String::new();
        m.render_into(&mut out);
        for family in [
            "flexa_http_request_duration_seconds",
            "flexa_job_queue_seconds",
            "flexa_job_service_seconds",
            "flexa_job_iteration_seconds",
        ] {
            assert!(out.contains(&format!("# TYPE {family} histogram")), "{family} typed");
            assert!(out.contains(&format!("{family}_count")), "{family} has _count");
            assert!(out.contains(&format!("{family}_sum")), "{family} has _sum");
            // The +Inf bucket is mandatory for Prometheus histograms.
            assert!(out.contains(&format!("{family}_bucket")), "{family} has buckets");
            assert!(
                out.lines().any(|l| l.starts_with(family) && l.contains("le=\"+Inf\"")),
                "{family} has +Inf"
            );
        }
        assert!(out.contains("flexa_obs_spans_dropped_total"));
        assert!(out.contains("# TYPE flexa_obs_spans_recorded_total counter"));
        assert!(out.contains("# TYPE flexa_obs_ring_spans gauge"));

        // service_under is conservative: good ≤ total, a zero threshold
        // admits nothing, and a generous one sees the 42 ms sample.
        // (The metrics instance is process-global, so no exact counts.)
        let (good_all, total) = m.service_under(u64::MAX);
        assert!(total >= 1);
        assert!(good_all <= total);
        assert!(good_all >= 1, "42 ms sample sits under a finite bucket bound");
        let (good_tiny, total_tiny) = m.service_under(0);
        assert_eq!(good_tiny, 0, "zero threshold counts nothing good");
        assert_eq!(total_tiny, total);
        // Cumulative monotonicity within one labeled series.
        let mut last = 0u64;
        let mut seen = 0;
        for line in out.lines() {
            if line.starts_with("flexa_job_queue_seconds_bucket{") {
                let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last, "buckets must be cumulative: {line}");
                last = v;
                seen += 1;
            }
        }
        assert_eq!(seen, BUCKET_BOUNDS_US.len() + 1, "full le series incl. +Inf");
    }
}
