//! Chrome trace-event export (Perfetto / `chrome://tracing` loadable).
//!
//! Renders ring snapshots as complete events (`"ph":"X"`) with `ts` and
//! `dur` in microseconds, `tid` = ring slot, and a caller-chosen `pid`.
//! The cluster router merges its own spans (pid 0) with each backend's
//! export (pid i+1) by splicing the inner event arrays: the wrapper is
//! exactly `{"traceEvents":[...]}` on every node, so the splice is a
//! prefix/suffix strip, not a JSON re-render. Clock domains differ
//! across nodes — Perfetto groups tracks by pid, and cross-node
//! correlation rides the shared `x-flexa-request-id` in `args`.

use super::span::Span;
use crate::serve::jobfile::esc;

/// Render one span as a single trace event object.
fn event_json(tid: u32, span: &Span, pid: u32) -> String {
    let mut args = String::new();
    if span.job != 0 {
        args.push_str(&format!("\"job\":{}", span.job));
    }
    for (key, val) in [
        ("tenant", span.tenant.as_str()),
        ("request", span.request_id.as_str()),
        ("detail", span.detail.as_str()),
    ] {
        if !val.is_empty() {
            if !args.is_empty() {
                args.push(',');
            }
            args.push_str(&format!("\"{key}\":\"{}\"", esc(val)));
        }
    }
    format!(
        "{{\"name\":\"{}\",\"cat\":\"flexa\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{pid},\"tid\":{tid},\"args\":{{{args}}}}}",
        esc(span.phase),
        span.start_us,
        span.dur_us,
    )
}

/// Append comma-separated event objects (no wrapper) to `out`.
pub fn render_events_into(spans: &[(u32, Span)], pid: u32, out: &mut String) {
    for (i, (tid, span)) in spans.iter().enumerate() {
        if i > 0 || !out.is_empty() {
            out.push(',');
        }
        out.push_str(&event_json(*tid, span, pid));
    }
}

/// Render a complete single-node trace document.
pub fn render(spans: &[(u32, Span)], pid: u32) -> String {
    let mut events = String::new();
    render_events_into(spans, pid, &mut events);
    format!("{{\"traceEvents\":[{events}]}}")
}

/// Extract the inner event list from a trace document produced by
/// [`render`] (used by the cluster router to splice backend traces
/// under their own pid without re-parsing). Returns `None` when the
/// body is not in the expected shape.
pub fn inner_events(doc: &str) -> Option<&str> {
    doc.trim().strip_prefix("{\"traceEvents\":[")?.strip_suffix("]}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::InlineStr;
    use crate::serve::jobfile::Json;

    fn mk(phase: &'static str, job: u64, tenant: &str, request: &str) -> (u32, Span) {
        (
            3,
            Span {
                phase,
                start_us: 1_500,
                dur_us: 250,
                job,
                tenant: InlineStr::new(tenant),
                request_id: InlineStr::new(request),
                detail: InlineStr::new("lasso"),
            },
        )
    }

    #[test]
    fn trace_round_trips_through_json_parse() {
        let spans = vec![mk("solve.iter", 7, "acme", "c1"), mk("kernel", 7, "", "")];
        let doc = render(&spans, 0);
        let parsed = Json::parse(&doc).expect("trace must be valid JSON");
        let events = match parsed.get("traceEvents") {
            Some(Json::Arr(a)) => a,
            other => panic!("traceEvents must be an array, got {other:?}"),
        };
        assert_eq!(events.len(), 2);
        let first = &events[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("solve.iter"));
        assert_eq!(first.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(first.get("ts").and_then(Json::as_f64), Some(1_500.0));
        assert_eq!(first.get("dur").and_then(Json::as_f64), Some(250.0));
        let args = first.get("args").expect("args object");
        assert_eq!(args.get("job").and_then(Json::as_f64), Some(7.0));
        assert_eq!(args.get("tenant").and_then(Json::as_str), Some("acme"));
        assert_eq!(args.get("request").and_then(Json::as_str), Some("c1"));
        // Empty fields are omitted, not rendered as "".
        let second = &events[1];
        assert!(second.get("args").and_then(|a| a.get("tenant")).is_none());
        assert!(second.get("args").and_then(|a| a.get("request")).is_none());
    }

    #[test]
    fn inner_events_strips_the_wrapper_exactly() {
        let spans = vec![mk("cluster.proxy", 0, "t", "c9")];
        let doc = render(&spans, 0);
        let inner = inner_events(&doc).expect("wrapper must strip");
        assert!(inner.starts_with("{\"name\":\"cluster.proxy\""));
        assert!(inner_events("{\"other\":[]}").is_none());
        assert_eq!(inner_events("{\"traceEvents\":[]}"), Some(""));
        // A merged document re-wraps to valid JSON.
        let merged = format!("{{\"traceEvents\":[{inner},{inner}]}}");
        assert!(Json::parse(&merged).is_ok());
    }
}
