//! Span primitives: fixed-size records, the process clock, and the
//! per-thread attribution context.
//!
//! A [`Span`] is a plain-old-data record — phase name (a `&'static str`
//! so rings never allocate), start/duration in microseconds on the
//! process-wide monotonic clock, and three bounded inline strings for
//! tenant, request id, and a free-form detail (endpoint label, backend
//! id, solver name). Fixed size keeps the ring buffer a flat `Vec` the
//! hot path can write without touching the allocator.
//!
//! Timestamps are offsets from a lazily-initialized process epoch
//! ([`init`] pins it early so `Instant`s taken before the first span —
//! e.g. a job's enqueue time — still convert). The epoch is an
//! `Instant`, never wall clock: NTP steps cannot tear a trace.

use std::cell::Cell;
use std::sync::OnceLock;
use std::time::Instant;

/// Maximum bytes kept for each inline string field (tenant, request
/// id, detail). Longer values truncate at a char boundary.
pub const INLINE_CAP: usize = 40;

/// A bounded, `Copy`, allocation-free string for span fields.
#[derive(Clone, Copy, Debug)]
pub struct InlineStr {
    len: u8,
    bytes: [u8; INLINE_CAP],
}

impl InlineStr {
    pub const EMPTY: InlineStr = InlineStr { len: 0, bytes: [0; INLINE_CAP] };

    /// Build from a `&str`, truncating to [`INLINE_CAP`] bytes at a
    /// UTF-8 char boundary.
    pub fn new(s: &str) -> Self {
        let mut end = s.len().min(INLINE_CAP);
        while end > 0 && !s.is_char_boundary(end) {
            end -= 1;
        }
        let mut bytes = [0u8; INLINE_CAP];
        bytes[..end].copy_from_slice(&s.as_bytes()[..end]);
        InlineStr { len: end as u8, bytes }
    }

    pub fn as_str(&self) -> &str {
        // Construction only ever copies a char-boundary-truncated
        // prefix of a valid &str, so this cannot fail.
        std::str::from_utf8(&self.bytes[..self.len as usize]).unwrap_or("")
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// One completed phase: `[start_us, start_us + dur_us)` on the process
/// monotonic clock, attributed to a job/tenant/request.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Phase label (`http.parse`, `queue.wait`, `solve.iter`, ...).
    pub phase: &'static str,
    /// Start offset from the process epoch, microseconds.
    pub start_us: u64,
    /// Duration, microseconds.
    pub dur_us: u64,
    /// Job id (0 = not attributed to a job).
    pub job: u64,
    pub tenant: InlineStr,
    pub request_id: InlineStr,
    /// Phase-specific annotation: endpoint, backend id, solver name.
    pub detail: InlineStr,
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Pin the process epoch now. Servers call this at bind time so every
/// later `Instant` (job enqueue stamps included) lands after it.
pub fn init() {
    let _ = epoch();
}

/// Microseconds since the process epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Convert an `Instant` to epoch-relative microseconds (0 if it
/// predates the epoch).
pub fn instant_us(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_micros() as u64).unwrap_or(0)
}

/// The attribution carried by every span a thread records.
#[derive(Clone, Copy, Debug)]
pub struct Ctx {
    pub job: u64,
    pub tenant: InlineStr,
    pub request_id: InlineStr,
}

impl Ctx {
    pub const NONE: Ctx =
        Ctx { job: 0, tenant: InlineStr::EMPTY, request_id: InlineStr::EMPTY };

    pub fn job(job: u64, tenant: &str) -> Ctx {
        Ctx { job, tenant: InlineStr::new(tenant), request_id: InlineStr::EMPTY }
    }

    pub fn request(request_id: &str, tenant: &str) -> Ctx {
        Ctx { job: 0, tenant: InlineStr::new(tenant), request_id: InlineStr::new(request_id) }
    }
}

thread_local! {
    static CTX: Cell<Ctx> = const { Cell::new(Ctx::NONE) };
    /// Kernel-time accumulator: `par` adds pool-region wall time here;
    /// the serve worker resets/takes it around each solve.
    static KERNEL_US: Cell<u64> = const { Cell::new(0) };
}

/// The calling thread's current attribution.
pub fn ctx() -> Ctx {
    CTX.with(|c| c.get())
}

/// Replace the calling thread's attribution; returns the previous one.
pub fn set_ctx(new: Ctx) -> Ctx {
    CTX.with(|c| c.replace(new))
}

/// Scoped attribution: restores the previous context on drop.
pub struct CtxGuard {
    prev: Ctx,
}

pub fn ctx_guard(new: Ctx) -> CtxGuard {
    CtxGuard { prev: set_ctx(new) }
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        set_ctx(self.prev);
    }
}

pub fn reset_kernel_us() {
    KERNEL_US.with(|k| k.set(0));
}

pub fn add_kernel_us(us: u64) {
    KERNEL_US.with(|k| k.set(k.get().saturating_add(us)));
}

/// Read and clear the thread's kernel-time accumulator.
pub fn take_kernel_us() -> u64 {
    KERNEL_US.with(|k| k.replace(0))
}

/// An in-flight span: records itself into the thread's ring on drop,
/// stamped with the context current at creation.
pub struct SpanGuard {
    phase: &'static str,
    start_us: u64,
    detail: InlineStr,
    ctx: Ctx,
}

/// Open a span for `phase` under the thread's current context.
pub fn span(phase: &'static str) -> SpanGuard {
    span_detail(phase, "")
}

/// Open a span with a phase-specific annotation.
pub fn span_detail(phase: &'static str, detail: &str) -> SpanGuard {
    SpanGuard { phase, start_us: now_us(), detail: InlineStr::new(detail), ctx: ctx() }
}

impl SpanGuard {
    /// Duration so far, microseconds.
    pub fn elapsed_us(&self) -> u64 {
        now_us().saturating_sub(self.start_us)
    }

    /// Replace the annotation (e.g. once the routed endpoint is known).
    pub fn set_detail(&mut self, detail: &str) {
        self.detail = InlineStr::new(detail);
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = now_us();
        super::ring::record(Span {
            phase: self.phase,
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            job: self.ctx.job,
            tenant: self.ctx.tenant,
            request_id: self.ctx.request_id,
            detail: self.detail,
        });
    }
}

/// Record a span retroactively (e.g. `queue.wait`, reconstructed from
/// the enqueue stamp once the job starts) under the current context.
pub fn record(phase: &'static str, start_us: u64, dur_us: u64, detail: &str) {
    let c = ctx();
    super::ring::record(Span {
        phase,
        start_us,
        dur_us,
        job: c.job,
        tenant: c.tenant,
        request_id: c.request_id,
        detail: InlineStr::new(detail),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_str_truncates_at_char_boundary() {
        let s = InlineStr::new("plain");
        assert_eq!(s.as_str(), "plain");
        // 39 ASCII bytes then a 3-byte char straddling the cap: the
        // whole char must be dropped, not split.
        let long = format!("{}\u{2603}tail", "x".repeat(39));
        let t = InlineStr::new(&long);
        assert_eq!(t.as_str(), "x".repeat(39));
        assert!(t.as_str().len() <= INLINE_CAP);
        assert!(InlineStr::EMPTY.is_empty());
    }

    #[test]
    fn ctx_guard_restores_previous_context() {
        let _outer = ctx_guard(Ctx::job(7, "acme"));
        assert_eq!(ctx().job, 7);
        {
            let _inner = ctx_guard(Ctx::request("req-1", "acme"));
            assert_eq!(ctx().job, 0);
            assert_eq!(ctx().request_id.as_str(), "req-1");
        }
        assert_eq!(ctx().job, 7);
        assert_eq!(ctx().tenant.as_str(), "acme");
    }

    #[test]
    fn kernel_accumulator_is_reset_and_taken() {
        reset_kernel_us();
        add_kernel_us(5);
        add_kernel_us(7);
        assert_eq!(take_kernel_us(), 12);
        assert_eq!(take_kernel_us(), 0);
    }

    #[test]
    fn clock_is_monotone_and_instant_converts() {
        init();
        let a = now_us();
        let t = std::time::Instant::now();
        let b = now_us();
        let tu = instant_us(t);
        assert!(a <= b);
        assert!(tu >= a && tu <= b.max(tu));
    }
}
