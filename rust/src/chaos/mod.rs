//! Seeded, deterministic fault injection for crash-tolerance testing.
//!
//! Chaos is **off** unless armed: either the `FLEXA_CHAOS=<seed>`
//! environment variable is set when the process first hits an injection
//! point, or a test installs a config programmatically via [`scoped`].
//! The inactive fast path is a single relaxed atomic load, so the hooks
//! compiled into `cluster::backend` and `tenant::store` cost nothing in
//! production.
//!
//! Faults are drawn from [`crate::prng::Xoshiro256pp`] streams keyed by
//! `(seed, site, per-site call counter)`, so a given seed produces the
//! same fault sequence at each site whenever the per-site call order is
//! deterministic (single prober thread, single replicator thread,
//! serialized test traffic). Sites currently wired:
//!
//! | site              | effect                                        |
//! |-------------------|-----------------------------------------------|
//! | `backend.connect` | reset (connect error) or slow-down            |
//! | `backend.read`    | reset after the request is written, or slow   |
//! | `proxy.stream`    | tear a proxied SSE stream mid-flight          |
//! | `store.open`      | corrupt or truncate a warm-start store image  |
//!
//! Tests in one binary share the process-global config, so every chaos
//! test — including golden, fault-free phases — must hold the exclusive
//! guard returned by [`scoped`] / [`scoped_off`]; the guard restores
//! the previous config on drop.

use crate::prng::Xoshiro256pp;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Per-site fault probabilities for one chaos run. All probabilities
/// are evaluated independently per call from the site's seeded stream.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Seed for every site's fault stream.
    pub seed: u64,
    /// P(connect attempt fails with a reset).
    pub connect_reset_p: f64,
    /// P(buffered exchange dies after the request is written).
    pub read_reset_p: f64,
    /// P(a proxied SSE stream tears mid-flight, per read).
    pub stream_reset_p: f64,
    /// P(a surviving call is delayed by `slow_ms`), drawn after the
    /// reset check from the same stream.
    pub slow_p: f64,
    /// Injected delay for slow faults.
    pub slow_ms: u64,
    /// P(a warm-start store image is mangled on open).
    pub store_corrupt_p: f64,
}

impl ChaosConfig {
    /// Moderate default rates: enough churn to exercise every failover
    /// path in a short run without starving the system of progress.
    pub fn from_seed(seed: u64) -> Self {
        ChaosConfig {
            seed,
            connect_reset_p: 0.10,
            read_reset_p: 0.05,
            stream_reset_p: 0.02,
            slow_p: 0.10,
            slow_ms: 15,
            store_corrupt_p: 0.25,
        }
    }
}

/// The outcome of one injection-point draw.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Proceed normally.
    None,
    /// Fail the operation as if the peer reset the connection.
    Reset,
    /// Sleep this long, then proceed.
    Slow(Duration),
}

struct ChaosState {
    config: Option<ChaosConfig>,
    /// Per-site call counters — the stream index for the next draw.
    /// A handful of fixed sites, so a linear scan beats a map.
    counters: Vec<(&'static str, u64)>,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<ChaosState> = Mutex::new(ChaosState { config: None, counters: Vec::new() });
/// Serializes chaos-sensitive tests within one binary.
static TEST_LOCK: Mutex<()> = Mutex::new(());
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn lock_state() -> MutexGuard<'static, ChaosState> {
    STATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Parse `FLEXA_CHAOS` once, installing a default-rate config when it
/// holds a seed. Called lazily from the first injection point so plain
/// library users never touch the environment.
fn env_init() {
    ENV_INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("FLEXA_CHAOS") {
            if let Ok(seed) = v.trim().parse::<u64>() {
                install(ChaosConfig::from_seed(seed));
            }
        }
    });
}

/// Whether any chaos config is currently installed.
pub fn active() -> bool {
    env_init();
    ACTIVE.load(Ordering::Relaxed)
}

/// Install `config`, resetting every site's call counter so the fault
/// sequence restarts from the stream head (reproducible runs).
pub fn install(config: ChaosConfig) {
    let mut st = lock_state();
    st.config = Some(config);
    st.counters.clear();
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Remove any installed config; every site reverts to `Fault::None`.
pub fn uninstall() {
    let mut st = lock_state();
    st.config = None;
    st.counters.clear();
    ACTIVE.store(false, Ordering::Relaxed);
}

/// Exclusive chaos scope for tests: holds the global chaos lock and
/// restores the previously installed config on drop.
pub struct Scoped {
    _guard: MutexGuard<'static, ()>,
    prev: Option<ChaosConfig>,
}

impl Drop for Scoped {
    fn drop(&mut self) {
        match self.prev {
            Some(cfg) => install(cfg),
            None => uninstall(),
        }
    }
}

fn scope_with(config: Option<ChaosConfig>) -> Scoped {
    let guard = TEST_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    env_init();
    let prev = lock_state().config;
    match config {
        Some(cfg) => install(cfg),
        None => uninstall(),
    }
    Scoped { _guard: guard, prev }
}

/// Run with `config` until the guard drops.
pub fn scoped(config: ChaosConfig) -> Scoped {
    scope_with(Some(config))
}

/// Run with chaos forced off (golden phases), even when `FLEXA_CHAOS`
/// is exported for the whole test process.
pub fn scoped_off() -> Scoped {
    scope_with(None)
}

fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The site's PRNG for its `n`-th call under `cfg.seed`.
fn site_rng(cfg: &ChaosConfig, site: &'static str, n: u64) -> Xoshiro256pp {
    let mut base = Xoshiro256pp::seed_from_u64(cfg.seed ^ fnv64(site.as_bytes()));
    base.split(n)
}

/// Draw the config and this call's stream index for `site`, or `None`
/// when chaos is inactive.
fn draw(site: &'static str) -> Option<(ChaosConfig, u64)> {
    if !active() {
        return None;
    }
    let mut st = lock_state();
    let cfg = st.config?;
    let slot = match st.counters.iter().position(|(s, _)| *s == site) {
        Some(i) => i,
        None => {
            st.counters.push((site, 0));
            st.counters.len() - 1
        }
    };
    let idx = st.counters[slot].1;
    st.counters[slot].1 += 1;
    Some((cfg, idx))
}

/// Decide the fault for one call at `site`. Inactive chaos returns
/// [`Fault::None`] after a single atomic load.
pub fn fault(site: &'static str) -> Fault {
    let Some((cfg, n)) = draw(site) else {
        return Fault::None;
    };
    let reset_p = match site {
        "backend.connect" => cfg.connect_reset_p,
        "backend.read" => cfg.read_reset_p,
        "proxy.stream" => cfg.stream_reset_p,
        _ => 0.0,
    };
    let mut rng = site_rng(&cfg, site, n);
    let r = rng.next_f64();
    if r < reset_p {
        Fault::Reset
    } else if r < reset_p + cfg.slow_p {
        Fault::Slow(Duration::from_millis(cfg.slow_ms))
    } else {
        Fault::None
    }
}

/// Maybe mangle a warm-start store image read at open: flip one byte
/// past the magic, or truncate the tail — the loader must survive both.
/// Returns true when the image was altered.
pub fn mangle_store(data: &mut Vec<u8>) -> bool {
    const PRESERVE: usize = 8; // keep the magic: corrupt records, not the file format
    let Some((cfg, n)) = draw("store.open") else {
        return false;
    };
    if data.len() <= PRESERVE + 1 {
        return false;
    }
    let mut rng = site_rng(&cfg, "store.open", n);
    if rng.next_f64() >= cfg.store_corrupt_p {
        return false;
    }
    let span = (data.len() - PRESERVE) as u64;
    if rng.next_below(2) == 0 {
        let at = PRESERVE + rng.next_below(span) as usize;
        data[at] ^= 0x5a;
    } else {
        let keep = PRESERVE + rng.next_below(span) as usize;
        data.truncate(keep);
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fixed seed yields the same fault sequence at a site, and
    /// different sites see decorrelated streams.
    #[test]
    fn fault_streams_are_deterministic_per_seed_and_site() {
        let cfg = ChaosConfig { connect_reset_p: 0.5, slow_p: 0.25, ..ChaosConfig::from_seed(42) };
        let run = |site: &'static str| {
            let _chaos = scoped(cfg);
            (0..32).map(|_| fault(site)).collect::<Vec<_>>()
        };
        let a1 = run("backend.connect");
        let a2 = run("backend.connect");
        assert_eq!(a1, a2, "same seed, same site → same sequence");
        assert!(a1.contains(&Fault::Reset), "p=0.5 over 32 draws fires");
        let b = run("backend.read");
        assert_ne!(a1, b, "sites draw from independent streams");
    }

    /// Outside a scope (and without FLEXA_CHAOS) every site is silent.
    #[test]
    fn inactive_chaos_injects_nothing() {
        let _off = scoped_off();
        for _ in 0..16 {
            assert_eq!(fault("backend.connect"), Fault::None);
        }
        let mut data = vec![0u8; 64];
        assert!(!mangle_store(&mut data));
        assert_eq!(data, vec![0u8; 64]);
    }

    /// Store mangling preserves the 8-byte magic prefix and actually
    /// alters the image when the probability is forced to 1.
    #[test]
    fn store_mangle_spares_the_magic() {
        let cfg = ChaosConfig { store_corrupt_p: 1.0, ..ChaosConfig::from_seed(9) };
        let _chaos = scoped(cfg);
        for _ in 0..16 {
            let clean: Vec<u8> = (0..96u8).collect();
            let mut data = clean.clone();
            assert!(mangle_store(&mut data));
            assert_eq!(&data[..8], &clean[..8], "magic untouched");
            assert_ne!(data, clean, "image altered");
        }
    }
}
