//! Sparse logistic regression
//! `min Σⱼ log(1 + exp(−aⱼ yⱼᵀ x)) + c‖x‖₁`
//! (Shevade & Keerthi 2003; Meier et al. 2008 — paper §2 fourth bullet).
//!
//! `F` is convex but *not quadratic*, so the exact best-response has no
//! closed form — this is the problem family that exercises the framework's
//! inexact subproblem solves (Theorem 1's εᵏ schedule).

use super::{BlockLayout, CompositeProblem, Regularizer};
use crate::linalg::{ops, power, DenseMatrix, MatVec};
use std::sync::OnceLock;

/// Numerically-stable `log(1 + e^{-z})`.
#[inline]
pub fn log1p_exp_neg(z: f64) -> f64 {
    if z > 0.0 {
        (-z).exp().ln_1p()
    } else {
        -z + z.exp().ln_1p()
    }
}

/// Logistic sigmoid `1 / (1 + e^{-z})`, stable for large |z|.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// ℓ₁-regularized logistic regression. The design matrix stores the rows
/// already scaled by their labels: `M[j,:] = aⱼ·yⱼᵀ`, so
/// `F(x) = Σⱼ log(1 + exp(−(Mx)ⱼ))`.
pub struct SparseLogReg<M: MatVec = DenseMatrix> {
    m: M,
    c: f64,
    layout: BlockLayout,
    col_sq: Vec<f64>,
    trace: f64,
    lambda_max: OnceLock<f64>,
    opt: Option<f64>,
}

impl<M: MatVec> SparseLogReg<M> {
    /// Build from a label-scaled design matrix (rows `aⱼ·yⱼᵀ`).
    pub fn new(m: M, c: f64) -> Self {
        Self::with_layout(m, c, None)
    }

    pub fn with_layout(m: M, c: f64, layout: Option<BlockLayout>) -> Self {
        assert!(c > 0.0, "SparseLogReg: c must be positive");
        let n = m.cols();
        let mut col_sq = vec![0.0; n];
        m.col_sq_norms(&mut col_sq);
        // Hessian diag: Σⱼ M_ji² σ(z)σ(−z) ≤ ‖M_j‖²/4; trace analogue /4.
        let trace = col_sq.iter().sum::<f64>() / 4.0;
        let layout = layout.unwrap_or_else(|| BlockLayout::scalar(n));
        assert_eq!(layout.dim(), n);
        Self { m, c, layout, col_sq, trace, lambda_max: OnceLock::new(), opt: None }
    }

    /// Attach a reference optimal value (computed by a long high-accuracy
    /// run; used for relative-error reporting).
    pub fn with_opt_value(mut self, v_star: f64) -> Self {
        self.opt = Some(v_star);
        self
    }

    /// Margins `z = Mx`.
    pub fn margins(&self, x: &[f64], z: &mut [f64]) {
        self.m.matvec(x, z);
    }

    pub fn samples(&self) -> usize {
        self.m.rows()
    }

    pub fn c(&self) -> f64 {
        self.c
    }
}

impl<M: MatVec> CompositeProblem for SparseLogReg<M> {
    fn n(&self) -> usize {
        self.m.cols()
    }

    fn layout(&self) -> &BlockLayout {
        &self.layout
    }

    fn smooth(&self, x: &[f64]) -> f64 {
        let mut z = vec![0.0; self.m.rows()];
        self.m.matvec(x, &mut z);
        z.iter().map(|&zi| log1p_exp_neg(zi)).sum()
    }

    fn reg(&self, x: &[f64]) -> f64 {
        self.c * ops::nrm1(x)
    }

    /// `∇F = Mᵀ w`, `wⱼ = −σ(−zⱼ)`.
    fn grad_smooth(&self, x: &[f64], g: &mut [f64]) {
        let mut z = vec![0.0; self.m.rows()];
        self.m.matvec(x, &mut z);
        for zi in z.iter_mut() {
            *zi = -sigmoid(-*zi);
        }
        self.m.matvec_t(&z, g);
    }

    /// One margin pass yields both `∇F` and `F` (hot-path fusion).
    fn grad_and_smooth(&self, x: &[f64], g: &mut [f64]) -> f64 {
        let mut z = vec![0.0; self.m.rows()];
        self.m.matvec(x, &mut z);
        let mut f = 0.0;
        for zi in z.iter_mut() {
            f += log1p_exp_neg(*zi);
            *zi = -sigmoid(-*zi);
        }
        self.m.matvec_t(&z, g);
        f
    }

    /// Upper bound on the Hessian diagonal: `‖M_j‖²/4`.
    fn curvature(&self, _x: &[f64], d: &mut [f64]) {
        for (o, &s) in d.iter_mut().zip(&self.col_sq) {
            *o = s / 4.0;
        }
    }

    fn lipschitz_grad(&self) -> f64 {
        *self
            .lambda_max
            .get_or_init(|| 0.25 * power::lambda_max_gram(&self.m, 1e-9, 500, 0x11C).lambda_max)
    }

    fn lipschitz_cached(&self) -> Option<f64> {
        self.lambda_max.get().copied()
    }

    fn seed_lipschitz(&self, l: f64) {
        let _ = self.lambda_max.set(l);
    }

    fn prox_block(&self, _i: usize, v: &[f64], t: f64, out: &mut [f64]) {
        let thr = t * self.c;
        for (o, &vi) in out.iter_mut().zip(v) {
            *o = ops::soft_threshold(vi, thr);
        }
    }

    fn regularizer(&self) -> Regularizer {
        Regularizer::L1 { c: self.c }
    }

    fn curvature_trace(&self) -> f64 {
        self.trace
    }

    fn opt_value(&self) -> Option<f64> {
        self.opt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn stable_scalar_functions() {
        assert!((log1p_exp_neg(0.0) - 2f64.ln()).abs() < 1e-12);
        // Large positive: ~0; large negative: ~ -z.
        assert!(log1p_exp_neg(800.0) < 1e-300);
        assert!((log1p_exp_neg(-800.0) - 800.0).abs() < 1e-9);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(800.0) <= 1.0);
        assert!(sigmoid(-800.0) >= 0.0);
        assert!((sigmoid(2.0) + sigmoid(-2.0) - 1.0).abs() < 1e-12);
    }

    fn problem() -> SparseLogReg {
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let mut m = DenseMatrix::randn(15, 8, &mut rng);
        // Scale rows by random labels.
        for j in 0..8 {
            for i in 0..15 {
                if i % 3 == 0 {
                    m.set(i, j, -m.get(i, j));
                }
            }
        }
        SparseLogReg::new(m, 0.3)
    }

    #[test]
    fn objective_positive_and_decreasing_along_gradient() {
        let p = problem();
        let x = vec![0.0; 8];
        let f0 = p.smooth(&x);
        assert!((f0 - 15.0 * 2f64.ln()).abs() < 1e-9, "F(0) = m log 2");
        let mut g = vec![0.0; 8];
        p.grad_smooth(&x, &mut g);
        // Small gradient step decreases F.
        let step = 1e-3;
        let x1: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi - step * gi).collect();
        assert!(p.smooth(&x1) < f0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = problem();
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let mut x = vec![0.0; 8];
        rng.fill_normal(&mut x);
        let mut g = vec![0.0; 8];
        p.grad_smooth(&x, &mut g);
        let h = 1e-6;
        for j in 0..8 {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let fd = (p.smooth(&xp) - p.smooth(&xm)) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-5, "coord {j}: {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn curvature_upper_bounds_fd_hessian_diag() {
        let p = problem();
        let x = vec![0.1; 8];
        let mut d = vec![0.0; 8];
        p.curvature(&x, &mut d);
        let h = 1e-4;
        let mut g_p = vec![0.0; 8];
        let mut g_m = vec![0.0; 8];
        for j in 0..8 {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            p.grad_smooth(&xp, &mut g_p);
            p.grad_smooth(&xm, &mut g_m);
            let hjj = (g_p[j] - g_m[j]) / (2.0 * h);
            assert!(hjj <= d[j] + 1e-6, "coord {j}: H_jj {hjj} > bound {}", d[j]);
            assert!(hjj >= 0.0, "convexity");
        }
        assert!(!p.is_quadratic());
    }
}
