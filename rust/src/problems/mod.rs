//! Composite problems `min V(x) = F(x) + G(x)` (paper eq. (1)).
//!
//! `F` is smooth (not necessarily convex), `G(x) = Σᵢ gᵢ(xᵢ)` is
//! block-separable convex, and the feasible set is a Cartesian product of
//! per-block sets (here `X = Rⁿ`, the setting of every experiment in the
//! paper). The four instances the paper lists are implemented:
//!
//! * [`lasso::Lasso`] — `F = ‖Ax−b‖²`, `G = c‖x‖₁` (the evaluation workload),
//! * [`group_lasso::GroupLasso`] — `G = c·Σᵢ‖xᵢ‖₂` over blocks,
//! * [`logreg::SparseLogReg`] — logistic loss + `c‖x‖₁`,
//! * [`svm::L1L2Svm`] — squared hinge loss + `c‖x‖₁`.

pub mod group_lasso;
pub mod lasso;
pub mod logreg;
pub mod svm;

use crate::linalg::ops;

/// Partition of the variable vector `0..n` into `N` contiguous blocks
/// (the paper's `x = (x₁, …, x_N)`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockLayout {
    /// `offsets[i]..offsets[i+1]` is block `i`; length `N + 1`.
    offsets: Vec<usize>,
}

impl BlockLayout {
    /// Uniform blocks of `block_size` variables (last block may be short).
    pub fn uniform(n: usize, block_size: usize) -> Self {
        assert!(block_size >= 1, "block_size must be >= 1");
        assert!(n >= 1, "empty layout");
        let mut offsets = Vec::with_capacity(n / block_size + 2);
        let mut o = 0;
        while o < n {
            offsets.push(o);
            o += block_size;
        }
        offsets.push(n);
        Self { offsets }
    }

    /// Scalar blocks (`nᵢ = 1`), the paper's Lasso setting.
    pub fn scalar(n: usize) -> Self {
        Self::uniform(n, 1)
    }

    /// Arbitrary block sizes.
    pub fn from_sizes(sizes: &[usize]) -> Self {
        assert!(!sizes.is_empty(), "at least one block");
        let mut offsets = Vec::with_capacity(sizes.len() + 1);
        let mut o = 0;
        offsets.push(0);
        for &s in sizes {
            assert!(s >= 1, "empty block");
            o += s;
            offsets.push(o);
        }
        Self { offsets }
    }

    /// Number of blocks `N`.
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total number of variables `n`.
    #[inline]
    pub fn dim(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Index range of block `i`.
    #[inline]
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.offsets[i]..self.offsets[i + 1]
    }

    /// Length of block `i`.
    #[inline]
    pub fn len(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Block containing variable `j`.
    pub fn block_of(&self, j: usize) -> usize {
        debug_assert!(j < self.dim());
        match self.offsets.binary_search(&j) {
            Ok(i) if i == self.num_blocks() => i - 1,
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// True if every block is a single variable.
    pub fn is_scalar(&self) -> bool {
        self.dim() == self.num_blocks()
    }
}

/// The block-separable regularizers used by the paper's instances.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Regularizer {
    /// `gᵢ(xᵢ) = c·‖xᵢ‖₁` (Lasso, sparse logistic regression, ℓ₁-SVM).
    L1 { c: f64 },
    /// `gᵢ(xᵢ) = c·‖xᵢ‖₂` (group Lasso).
    GroupL2 { c: f64 },
}

impl Regularizer {
    /// Regularizer value over the whole vector given a layout.
    pub fn value(&self, x: &[f64], layout: &BlockLayout) -> f64 {
        match *self {
            Regularizer::L1 { c } => c * ops::nrm1(x),
            Regularizer::GroupL2 { c } => {
                let mut s = 0.0;
                for i in 0..layout.num_blocks() {
                    s += ops::nrm2(&x[layout.range(i)]);
                }
                c * s
            }
        }
    }

    /// Block proximal operator: `argmin_z ½‖z−v‖² + t·gᵢ(z)` into `out`.
    pub fn prox_block(&self, v: &[f64], t: f64, out: &mut [f64]) {
        match *self {
            Regularizer::L1 { c } => {
                let thr = t * c;
                for (o, &vi) in out.iter_mut().zip(v) {
                    *o = ops::soft_threshold(vi, thr);
                }
            }
            Regularizer::GroupL2 { c } => ops::group_soft_threshold(v, t * c, out),
        }
    }

    /// The weight `c`.
    pub fn weight(&self) -> f64 {
        match *self {
            Regularizer::L1 { c } | Regularizer::GroupL2 { c } => c,
        }
    }
}

/// A composite optimization problem (paper eq. (1)) over `X = Rⁿ`.
///
/// The interface exposes exactly what the algorithmic framework needs:
/// objective pieces, the full gradient of `F` (Algorithm 1 computes all
/// block best-responses each iteration, so the full gradient is the
/// natural unit of work), per-coordinate surrogate curvatures for the `Pᵢ`
/// choices, and the block prox of `G`.
pub trait CompositeProblem: Sync {
    /// Number of variables.
    fn n(&self) -> usize;
    /// Block partition.
    fn layout(&self) -> &BlockLayout;
    /// Smooth part `F(x)`.
    fn smooth(&self, x: &[f64]) -> f64;
    /// Nonsmooth part `G(x)`.
    fn reg(&self, x: &[f64]) -> f64;
    /// `V(x) = F(x) + G(x)`.
    fn objective(&self, x: &[f64]) -> f64 {
        self.smooth(x) + self.reg(x)
    }
    /// Full gradient `∇F(x)` into `g`.
    fn grad_smooth(&self, x: &[f64], g: &mut [f64]);
    /// Fused `∇F(x)` + `F(x)` — one residual/margin pass instead of two
    /// (the hot-path entry point; overridden by every concrete problem).
    fn grad_and_smooth(&self, x: &[f64], g: &mut [f64]) -> f64 {
        self.grad_smooth(x, g);
        self.smooth(x)
    }
    /// Per-coordinate surrogate curvature `d_j` at `x` — the diagonal
    /// second-order model used by the "exact"/Newton-flavoured `Pᵢ`
    /// (for quadratic `F` this makes the scalar-block best-response exact,
    /// paper eq. (6)).
    fn curvature(&self, x: &[f64], d: &mut [f64]);
    /// Gradient Lipschitz constant `L_F` (FISTA/ISTA step size).
    fn lipschitz_grad(&self) -> f64;
    /// The Lipschitz constant if it has already been computed for this
    /// instance, without triggering the (power-iteration) computation.
    /// Lets a serving layer carry the spectral-norm estimate across
    /// solves on the same data (`None` = not computed / not cacheable).
    fn lipschitz_cached(&self) -> Option<f64> {
        None
    }
    /// Seed the Lipschitz cache with a value previously computed on an
    /// *identical* instance: [`Self::lipschitz_grad`] then returns it
    /// verbatim and skips the power-iteration preamble. No-op for
    /// problems without a cache slot. Power iteration is deterministic,
    /// so seeding never changes results — only setup time.
    fn seed_lipschitz(&self, _l: f64) {}
    /// Block prox: `argmin_z ½‖z−v‖² + t·gᵢ(z)`.
    fn prox_block(&self, i: usize, v: &[f64], t: f64, out: &mut [f64]);
    /// The regularizer (weight + shape).
    fn regularizer(&self) -> Regularizer;
    /// `tr(AᵀA)`-style curvature trace for the paper's τ initialization
    /// (`τᵢ = tr(AᵀA)/2n` for Lasso).
    fn curvature_trace(&self) -> f64;
    /// True if `F` is quadratic, so the diagonal model with `d_j` is the
    /// exact scalar-block best-response.
    fn is_quadratic(&self) -> bool {
        false
    }
    /// Known optimal value `V*` for planted instances (drives the
    /// relative-error metric of Fig. 1).
    fn opt_value(&self) -> Option<f64> {
        None
    }
}

/// Extension trait for `F(x) = ‖Ax − b‖²` problems: exposes the residual
/// structure the sequential baselines (Gauss–Seidel, ADMM) exploit for
/// `O(m)` single-coordinate updates.
pub trait LeastSquares: CompositeProblem {
    /// `r = Ax − b` into `r`.
    fn residual(&self, x: &[f64], r: &mut [f64]);
    /// Right-hand side `b`.
    fn rhs(&self) -> &[f64];
    /// Rows of `A` / length of the residual.
    fn rows(&self) -> usize;
    /// `A_jᵀ v` for a single column.
    fn col_dot(&self, j: usize, v: &[f64]) -> f64;
    /// `r += alpha · A_j`.
    fn col_axpy(&self, j: usize, alpha: f64, r: &mut [f64]);
    /// `‖A_j‖²` per column (precomputed).
    fn col_sq_norms(&self) -> &[f64];
    /// `y = A v`.
    fn apply(&self, v: &[f64], y: &mut [f64]);
    /// `y = Aᵀ v`.
    fn apply_t(&self, v: &[f64], y: &mut [f64]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_uniform_and_scalar() {
        let l = BlockLayout::uniform(10, 3);
        assert_eq!(l.num_blocks(), 4);
        assert_eq!(l.dim(), 10);
        assert_eq!(l.range(0), 0..3);
        assert_eq!(l.range(3), 9..10);
        assert_eq!(l.len(3), 1);
        assert!(!l.is_scalar());
        let s = BlockLayout::scalar(5);
        assert_eq!(s.num_blocks(), 5);
        assert!(s.is_scalar());
    }

    #[test]
    fn layout_block_of() {
        let l = BlockLayout::from_sizes(&[2, 3, 1]);
        assert_eq!(l.dim(), 6);
        let blocks: Vec<usize> = (0..6).map(|j| l.block_of(j)).collect();
        assert_eq!(blocks, vec![0, 0, 1, 1, 1, 2]);
    }

    #[test]
    fn l1_regularizer_value_and_prox() {
        let l = BlockLayout::scalar(3);
        let r = Regularizer::L1 { c: 2.0 };
        assert_eq!(r.value(&[1.0, -2.0, 0.5], &l), 7.0);
        let mut out = vec![0.0];
        r.prox_block(&[3.0], 0.5, &mut out); // threshold 1.0
        assert_eq!(out, vec![2.0]);
        assert_eq!(r.weight(), 2.0);
    }

    #[test]
    fn group_regularizer_value_and_prox() {
        let l = BlockLayout::uniform(4, 2);
        let r = Regularizer::GroupL2 { c: 1.0 };
        // blocks [3,4] (norm 5) and [0,0] (norm 0)
        assert_eq!(r.value(&[3.0, 4.0, 0.0, 0.0], &l), 5.0);
        let mut out = vec![0.0; 2];
        r.prox_block(&[3.0, 4.0], 2.5, &mut out);
        assert!((ops::nrm2(&out) - 2.5).abs() < 1e-12);
    }
}
