//! The Lasso problem `min ‖Ax − b‖² + c‖x‖₁` — the paper's evaluation
//! workload (Tibshirani 1996, paper §2 second bullet).

use super::{BlockLayout, CompositeProblem, LeastSquares, Regularizer};
use crate::linalg::{ops, power, DenseMatrix, MatVec};
use std::sync::OnceLock;

/// Lasso over a dense or sparse design matrix.
pub struct Lasso<M: MatVec = DenseMatrix> {
    a: M,
    b: Vec<f64>,
    c: f64,
    layout: BlockLayout,
    col_sq: Vec<f64>,
    trace_gram: f64,
    /// `λ_max(AᵀA)` cache — the power method runs once on first use.
    lambda_max: OnceLock<f64>,
    /// Known optimum for planted instances.
    opt: Option<f64>,
}

impl<M: MatVec> Lasso<M> {
    /// Scalar-block Lasso (paper's Fig. 1 setting).
    pub fn new(a: M, b: Vec<f64>, c: f64) -> Self {
        Self::with_layout(a, b, c, None)
    }

    /// Lasso with an explicit block layout (blocks only affect the
    /// decomposition, not the objective).
    pub fn with_layout(a: M, b: Vec<f64>, c: f64, layout: Option<BlockLayout>) -> Self {
        assert_eq!(a.rows(), b.len(), "Lasso: A rows must match b length");
        assert!(c > 0.0, "Lasso: c must be positive");
        let n = a.cols();
        let mut col_sq = vec![0.0; n];
        a.col_sq_norms(&mut col_sq);
        let trace_gram = col_sq.iter().sum();
        let layout = layout.unwrap_or_else(|| BlockLayout::scalar(n));
        assert_eq!(layout.dim(), n, "Lasso: layout must cover all columns");
        Self { a, b, c, layout, col_sq, trace_gram, lambda_max: OnceLock::new(), opt: None }
    }

    /// Attach the known optimal value (planted instances).
    pub fn with_opt_value(mut self, v_star: f64) -> Self {
        self.opt = Some(v_star);
        self
    }

    /// Design matrix access.
    pub fn matrix(&self) -> &M {
        &self.a
    }

    /// Regularization weight.
    pub fn c(&self) -> f64 {
        self.c
    }
}

impl<M: MatVec> CompositeProblem for Lasso<M> {
    fn n(&self) -> usize {
        self.a.cols()
    }

    fn layout(&self) -> &BlockLayout {
        &self.layout
    }

    fn smooth(&self, x: &[f64]) -> f64 {
        let mut r = vec![0.0; self.a.rows()];
        self.residual(x, &mut r);
        ops::nrm2_sq(&r)
    }

    fn reg(&self, x: &[f64]) -> f64 {
        self.c * ops::nrm1(x)
    }

    /// `∇F = 2Aᵀ(Ax − b)`.
    fn grad_smooth(&self, x: &[f64], g: &mut [f64]) {
        let mut r = vec![0.0; self.a.rows()];
        self.residual(x, &mut r);
        self.a.matvec_t(&r, g);
        ops::scal(2.0, g);
    }

    /// One residual pass yields both `∇F` and `F` (hot-path fusion).
    fn grad_and_smooth(&self, x: &[f64], g: &mut [f64]) -> f64 {
        let mut r = vec![0.0; self.a.rows()];
        self.residual(x, &mut r);
        let f = ops::nrm2_sq(&r);
        self.a.matvec_t(&r, g);
        ops::scal(2.0, g);
        f
    }

    /// `d_j = 2‖A_j‖²` — the exact diagonal of `∇²F`.
    fn curvature(&self, _x: &[f64], d: &mut [f64]) {
        for (o, &s) in d.iter_mut().zip(&self.col_sq) {
            *o = 2.0 * s;
        }
    }

    fn lipschitz_grad(&self) -> f64 {
        *self
            .lambda_max
            .get_or_init(|| 2.0 * power::lambda_max_gram(&self.a, 1e-9, 500, 0x11A).lambda_max)
    }

    fn lipschitz_cached(&self) -> Option<f64> {
        self.lambda_max.get().copied()
    }

    fn seed_lipschitz(&self, l: f64) {
        let _ = self.lambda_max.set(l);
    }

    fn prox_block(&self, _i: usize, v: &[f64], t: f64, out: &mut [f64]) {
        let thr = t * self.c;
        for (o, &vi) in out.iter_mut().zip(v) {
            *o = ops::soft_threshold(vi, thr);
        }
    }

    fn regularizer(&self) -> Regularizer {
        Regularizer::L1 { c: self.c }
    }

    fn curvature_trace(&self) -> f64 {
        self.trace_gram
    }

    fn is_quadratic(&self) -> bool {
        true
    }

    fn opt_value(&self) -> Option<f64> {
        self.opt
    }
}

impl<M: MatVec> LeastSquares for Lasso<M> {
    fn residual(&self, x: &[f64], r: &mut [f64]) {
        self.a.matvec(x, r);
        for (ri, bi) in r.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
    }

    fn rhs(&self) -> &[f64] {
        &self.b
    }

    fn rows(&self) -> usize {
        self.a.rows()
    }

    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        self.a.dot_col(j, v)
    }

    fn col_axpy(&self, j: usize, alpha: f64, r: &mut [f64]) {
        self.a.axpy_col(j, alpha, r);
    }

    fn col_sq_norms(&self) -> &[f64] {
        &self.col_sq
    }

    fn apply(&self, v: &[f64], y: &mut [f64]) {
        self.a.matvec(v, y);
    }

    fn apply_t(&self, v: &[f64], y: &mut [f64]) {
        self.a.matvec_t(v, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    fn tiny() -> Lasso {
        // A = [[1, 0], [0, 2]], b = [1, 2], c = 1
        let a = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 2.0]);
        Lasso::new(a, vec![1.0, 2.0], 1.0)
    }

    #[test]
    fn objective_pieces() {
        let p = tiny();
        let x = vec![1.0, 1.0];
        // Ax - b = [0, 0]; F = 0; G = 2.
        assert_eq!(p.smooth(&x), 0.0);
        assert_eq!(p.reg(&x), 2.0);
        assert_eq!(p.objective(&x), 2.0);
        let x0 = vec![0.0, 0.0];
        assert_eq!(p.smooth(&x0), 5.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let a = DenseMatrix::randn(8, 5, &mut rng);
        let mut b = vec![0.0; 8];
        rng.fill_normal(&mut b);
        let p = Lasso::new(a, b, 0.5);
        let mut x = vec![0.0; 5];
        rng.fill_normal(&mut x);
        let mut g = vec![0.0; 5];
        p.grad_smooth(&x, &mut g);
        let h = 1e-6;
        for j in 0..5 {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let fd = (p.smooth(&xp) - p.smooth(&xm)) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-4, "coord {j}: fd {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn curvature_is_hessian_diagonal() {
        let p = tiny();
        let mut d = vec![0.0; 2];
        p.curvature(&[0.0, 0.0], &mut d);
        assert_eq!(d, vec![2.0, 8.0]); // 2*||A_j||^2
        assert_eq!(p.curvature_trace(), 5.0);
        assert!(p.is_quadratic());
    }

    #[test]
    fn lipschitz_upper_bounds_curvature() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let a = DenseMatrix::randn(20, 10, &mut rng);
        let p = Lasso::new(a, vec![0.0; 20], 1.0);
        let l = p.lipschitz_grad();
        let mut d = vec![0.0; 10];
        p.curvature(&[0.0; 10], &mut d);
        let dmax = d.iter().cloned().fold(0.0, f64::max);
        assert!(l >= dmax - 1e-6, "L = {l} < max d = {dmax}");
        // Cached on second call.
        assert_eq!(p.lipschitz_grad(), l);
    }

    #[test]
    fn residual_maintenance_consistency() {
        let p = tiny();
        let x = vec![0.5, -0.5];
        let mut r = vec![0.0; 2];
        p.residual(&x, &mut r);
        assert_eq!(r, vec![-0.5, -3.0]);
        // col_axpy updates residual exactly like recomputing it.
        let mut r2 = r.clone();
        p.col_axpy(1, 1.0, &mut r2); // x1 += 1
        let mut r3 = vec![0.0; 2];
        p.residual(&[0.5, 0.5], &mut r3);
        assert_eq!(r2, r3);
        assert_eq!(p.col_dot(1, &r), -6.0);
    }

    #[test]
    fn prox_block_soft_threshold() {
        let p = tiny();
        let mut out = vec![0.0; 1];
        p.prox_block(0, &[2.0], 0.5, &mut out);
        assert_eq!(out, vec![1.5]);
        assert_eq!(p.opt_value(), None);
        let p2 = tiny().with_opt_value(1.25);
        assert_eq!(p2.opt_value(), Some(1.25));
    }
}
