//! The group Lasso problem `min ‖Ax − b‖² + c·Σᵢ‖xᵢ‖₂`
//! (Yuan & Lin 2006, paper §2 third bullet).

use super::{BlockLayout, CompositeProblem, LeastSquares, Regularizer};
use crate::linalg::{ops, power, DenseMatrix, MatVec};
use std::sync::OnceLock;

/// Group Lasso with an arbitrary block layout.
pub struct GroupLasso<M: MatVec = DenseMatrix> {
    a: M,
    b: Vec<f64>,
    c: f64,
    layout: BlockLayout,
    col_sq: Vec<f64>,
    /// Per-block curvature bound `d_i = 2·λ_max(A_iᵀA_i)` upper-bounded by
    /// `2·Σ_{j∈i}‖A_j‖²` (trace bound; exact for scalar blocks).
    block_curv: Vec<f64>,
    trace_gram: f64,
    lambda_max: OnceLock<f64>,
    opt: Option<f64>,
}

impl<M: MatVec> GroupLasso<M> {
    /// Equal-size blocks of `block_size` variables.
    pub fn new(a: M, b: Vec<f64>, c: f64, block_size: usize) -> Self {
        let layout = BlockLayout::uniform(a.cols(), block_size);
        Self::with_layout(a, b, c, layout)
    }

    /// Explicit layout.
    pub fn with_layout(a: M, b: Vec<f64>, c: f64, layout: BlockLayout) -> Self {
        assert_eq!(a.rows(), b.len(), "GroupLasso: A rows must match b length");
        assert!(c > 0.0, "GroupLasso: c must be positive");
        assert_eq!(layout.dim(), a.cols(), "GroupLasso: layout must cover all columns");
        let n = a.cols();
        let mut col_sq = vec![0.0; n];
        a.col_sq_norms(&mut col_sq);
        let trace_gram = col_sq.iter().sum();
        // Exact per-block curvature 2·λ_max(A_iᵀA_i) for small blocks
        // (power iteration on the w×w block Gram — w is the block size,
        // so this is O(n·w·m) once); the trace bound for large blocks.
        let block_curv = (0..layout.num_blocks())
            .map(|i| {
                let r = layout.range(i);
                let w = r.len();
                let trace_bound = 2.0 * r.clone().map(|j| col_sq[j]).sum::<f64>();
                if w == 1 {
                    return trace_bound; // exact for scalars
                }
                if w > 32 {
                    return trace_bound;
                }
                // Form the block Gram.
                let mut gram = vec![0.0; w * w];
                let mut cols: Vec<Vec<f64>> = Vec::with_capacity(w);
                for j in r.clone() {
                    let mut col = vec![0.0; a.rows()];
                    a.axpy_col(j, 1.0, &mut col);
                    cols.push(col);
                }
                for p in 0..w {
                    for q in p..w {
                        let v = crate::linalg::ops::dot(&cols[p], &cols[q]);
                        gram[p * w + q] = v;
                        gram[q * w + p] = v;
                    }
                }
                // Power iteration on the symmetric PSD gram.
                let mut v = vec![1.0 / (w as f64).sqrt(); w];
                let mut lam = 0.0;
                for _ in 0..50 {
                    let mut gv = vec![0.0; w];
                    for p in 0..w {
                        let mut s = 0.0;
                        for q in 0..w {
                            s += gram[p * w + q] * v[q];
                        }
                        gv[p] = s;
                    }
                    let nrm = crate::linalg::ops::nrm2(&gv);
                    if nrm == 0.0 {
                        break;
                    }
                    for p in 0..w {
                        v[p] = gv[p] / nrm;
                    }
                    lam = nrm;
                }
                (2.0 * lam).min(trace_bound).max(1e-12)
            })
            .collect();
        Self { a, b, c, layout, col_sq, block_curv, trace_gram, lambda_max: OnceLock::new(), opt: None }
    }

    /// Attach the known optimal value (planted instances).
    pub fn with_opt_value(mut self, v_star: f64) -> Self {
        self.opt = Some(v_star);
        self
    }

    /// Per-block curvature bounds (used by the FPA surrogate).
    pub fn block_curvatures(&self) -> &[f64] {
        &self.block_curv
    }

    pub fn c(&self) -> f64 {
        self.c
    }
}

impl<M: MatVec> CompositeProblem for GroupLasso<M> {
    fn n(&self) -> usize {
        self.a.cols()
    }

    fn layout(&self) -> &BlockLayout {
        &self.layout
    }

    fn smooth(&self, x: &[f64]) -> f64 {
        let mut r = vec![0.0; self.a.rows()];
        self.residual(x, &mut r);
        ops::nrm2_sq(&r)
    }

    fn reg(&self, x: &[f64]) -> f64 {
        Regularizer::GroupL2 { c: self.c }.value(x, &self.layout)
    }

    fn grad_smooth(&self, x: &[f64], g: &mut [f64]) {
        let mut r = vec![0.0; self.a.rows()];
        self.residual(x, &mut r);
        self.a.matvec_t(&r, g);
        ops::scal(2.0, g);
    }

    /// One residual pass yields both `∇F` and `F` (hot-path fusion).
    fn grad_and_smooth(&self, x: &[f64], g: &mut [f64]) -> f64 {
        let mut r = vec![0.0; self.a.rows()];
        self.residual(x, &mut r);
        let f = ops::nrm2_sq(&r);
        self.a.matvec_t(&r, g);
        ops::scal(2.0, g);
        f
    }

    /// Per-coordinate value is the enclosing block's curvature bound, so
    /// block-wise surrogates can read any coordinate of the block.
    fn curvature(&self, _x: &[f64], d: &mut [f64]) {
        for i in 0..self.layout.num_blocks() {
            let c = self.block_curv[i];
            for j in self.layout.range(i) {
                d[j] = c;
            }
        }
    }

    fn lipschitz_grad(&self) -> f64 {
        *self
            .lambda_max
            .get_or_init(|| 2.0 * power::lambda_max_gram(&self.a, 1e-9, 500, 0x11B).lambda_max)
    }

    fn lipschitz_cached(&self) -> Option<f64> {
        self.lambda_max.get().copied()
    }

    fn seed_lipschitz(&self, l: f64) {
        let _ = self.lambda_max.set(l);
    }

    fn prox_block(&self, _i: usize, v: &[f64], t: f64, out: &mut [f64]) {
        ops::group_soft_threshold(v, t * self.c, out);
    }

    fn regularizer(&self) -> Regularizer {
        Regularizer::GroupL2 { c: self.c }
    }

    fn curvature_trace(&self) -> f64 {
        self.trace_gram
    }

    fn is_quadratic(&self) -> bool {
        true
    }

    fn opt_value(&self) -> Option<f64> {
        self.opt
    }
}

impl<M: MatVec> LeastSquares for GroupLasso<M> {
    fn residual(&self, x: &[f64], r: &mut [f64]) {
        self.a.matvec(x, r);
        for (ri, bi) in r.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
    }
    fn rhs(&self) -> &[f64] {
        &self.b
    }
    fn rows(&self) -> usize {
        self.a.rows()
    }
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        self.a.dot_col(j, v)
    }
    fn col_axpy(&self, j: usize, alpha: f64, r: &mut [f64]) {
        self.a.axpy_col(j, alpha, r);
    }
    fn col_sq_norms(&self) -> &[f64] {
        &self.col_sq
    }
    fn apply(&self, v: &[f64], y: &mut [f64]) {
        self.a.matvec(v, y);
    }
    fn apply_t(&self, v: &[f64], y: &mut [f64]) {
        self.a.matvec_t(v, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    fn problem() -> GroupLasso {
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let a = DenseMatrix::randn(10, 6, &mut rng);
        let mut b = vec![0.0; 10];
        rng.fill_normal(&mut b);
        GroupLasso::new(a, b, 0.7, 2)
    }

    #[test]
    fn layout_and_reg_value() {
        let p = problem();
        assert_eq!(p.layout().num_blocks(), 3);
        let x = vec![3.0, 4.0, 0.0, 0.0, 1.0, 0.0];
        // G = 0.7 * (5 + 0 + 1)
        assert!((p.reg(&x) - 0.7 * 6.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = problem();
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let mut x = vec![0.0; 6];
        rng.fill_normal(&mut x);
        let mut g = vec![0.0; 6];
        p.grad_smooth(&x, &mut g);
        let h = 1e-6;
        for j in 0..6 {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let fd = (p.smooth(&xp) - p.smooth(&xm)) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn block_curvature_bounds_block_gram() {
        let p = problem();
        let mut d = vec![0.0; 6];
        p.curvature(&[0.0; 6], &mut d);
        // Within a block all coordinates share the bound.
        assert_eq!(d[0], d[1]);
        assert_eq!(d[2], d[3]);
        let cs = p.col_sq_norms();
        // 2·λ_max of the block gram: between the largest column norm and
        // the trace bound.
        assert!(d[0] <= 2.0 * (cs[0] + cs[1]) + 1e-9);
        assert!(d[0] >= 2.0 * cs[0].max(cs[1]) - 1e-6);
        // L_F upper-bounds... the global curvature trace bound is larger.
        assert!(p.lipschitz_grad() <= 2.0 * p.curvature_trace() + 1e-9);
        // Every block curvature is below the global Lipschitz constant.
        for i in 0..3 {
            assert!(p.block_curvatures()[i] <= p.lipschitz_grad() + 1e-6);
        }
    }

    #[test]
    fn prox_is_group_soft_threshold() {
        let p = problem();
        let mut out = vec![0.0; 2];
        p.prox_block(0, &[3.0, 4.0], 1.0, &mut out); // threshold 0.7
        let scale: f64 = 1.0 - 0.7 / 5.0;
        assert!((out[0] - 3.0 * scale).abs() < 1e-12);
        assert!((out[1] - 4.0 * scale).abs() < 1e-12);
    }
}
