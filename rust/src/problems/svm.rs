//! ℓ₁-regularized ℓ₂-loss support vector machine
//! `min Σⱼ max(0, 1 − aⱼ yⱼᵀ x)² + c‖x‖₁`
//! (Yuan et al. 2010 — paper §2 fifth bullet).
//!
//! The squared hinge loss is `C¹` with Lipschitz gradient but only
//! piecewise quadratic, exercising the framework beyond the pure
//! least-squares case while keeping a cheap curvature surrogate.

use super::{BlockLayout, CompositeProblem, Regularizer};
use crate::linalg::{ops, power, DenseMatrix, MatVec};
use std::sync::OnceLock;

/// ℓ₁-regularized squared-hinge SVM. Rows of `m` are the label-scaled
/// samples `aⱼ·yⱼᵀ` with `aⱼ ∈ {−1, 1}`, so the margins are `z = Mx` and
/// `F(x) = Σⱼ max(0, 1 − zⱼ)²`.
pub struct L1L2Svm<M: MatVec = DenseMatrix> {
    m: M,
    c: f64,
    layout: BlockLayout,
    col_sq: Vec<f64>,
    trace: f64,
    lambda_max: OnceLock<f64>,
    opt: Option<f64>,
}

impl<M: MatVec> L1L2Svm<M> {
    /// Build from a label-scaled sample matrix.
    pub fn new(m: M, c: f64) -> Self {
        assert!(c > 0.0, "L1L2Svm: c must be positive");
        let n = m.cols();
        let mut col_sq = vec![0.0; n];
        m.col_sq_norms(&mut col_sq);
        // max curvature of the squared hinge along coordinate j: 2‖M_j‖².
        let trace = 2.0 * col_sq.iter().sum::<f64>();
        let layout = BlockLayout::scalar(n);
        Self { m, c, layout, col_sq, trace, lambda_max: OnceLock::new(), opt: None }
    }

    /// Attach a reference optimal value for relative-error reporting.
    pub fn with_opt_value(mut self, v_star: f64) -> Self {
        self.opt = Some(v_star);
        self
    }

    pub fn samples(&self) -> usize {
        self.m.rows()
    }

    pub fn c(&self) -> f64 {
        self.c
    }
}

impl<M: MatVec> CompositeProblem for L1L2Svm<M> {
    fn n(&self) -> usize {
        self.m.cols()
    }

    fn layout(&self) -> &BlockLayout {
        &self.layout
    }

    fn smooth(&self, x: &[f64]) -> f64 {
        let mut z = vec![0.0; self.m.rows()];
        self.m.matvec(x, &mut z);
        z.iter()
            .map(|&zi| {
                let v = (1.0 - zi).max(0.0);
                v * v
            })
            .sum()
    }

    fn reg(&self, x: &[f64]) -> f64 {
        self.c * ops::nrm1(x)
    }

    /// `∇F = Mᵀ w`, `wⱼ = −2·max(0, 1 − zⱼ)`.
    fn grad_smooth(&self, x: &[f64], g: &mut [f64]) {
        let mut z = vec![0.0; self.m.rows()];
        self.m.matvec(x, &mut z);
        for zi in z.iter_mut() {
            *zi = -2.0 * (1.0 - *zi).max(0.0);
        }
        self.m.matvec_t(&z, g);
    }

    /// One margin pass yields both `∇F` and `F` (hot-path fusion).
    fn grad_and_smooth(&self, x: &[f64], g: &mut [f64]) -> f64 {
        let mut z = vec![0.0; self.m.rows()];
        self.m.matvec(x, &mut z);
        let mut f = 0.0;
        for zi in z.iter_mut() {
            let v = (1.0 - *zi).max(0.0);
            f += v * v;
            *zi = -2.0 * v;
        }
        self.m.matvec_t(&z, g);
        f
    }

    /// Curvature bound `2‖M_j‖²` (active-set Hessian diagonal bound).
    fn curvature(&self, _x: &[f64], d: &mut [f64]) {
        for (o, &s) in d.iter_mut().zip(&self.col_sq) {
            *o = 2.0 * s;
        }
    }

    fn lipschitz_grad(&self) -> f64 {
        *self
            .lambda_max
            .get_or_init(|| 2.0 * power::lambda_max_gram(&self.m, 1e-9, 500, 0x11D).lambda_max)
    }

    fn lipschitz_cached(&self) -> Option<f64> {
        self.lambda_max.get().copied()
    }

    fn seed_lipschitz(&self, l: f64) {
        let _ = self.lambda_max.set(l);
    }

    fn prox_block(&self, _i: usize, v: &[f64], t: f64, out: &mut [f64]) {
        let thr = t * self.c;
        for (o, &vi) in out.iter_mut().zip(v) {
            *o = ops::soft_threshold(vi, thr);
        }
    }

    fn regularizer(&self) -> Regularizer {
        Regularizer::L1 { c: self.c }
    }

    fn curvature_trace(&self) -> f64 {
        self.trace
    }

    fn opt_value(&self) -> Option<f64> {
        self.opt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    fn problem() -> L1L2Svm {
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        let mut m = DenseMatrix::randn(12, 6, &mut rng);
        for j in 0..6 {
            for i in 0..12 {
                if i % 2 == 0 {
                    m.set(i, j, -m.get(i, j));
                }
            }
        }
        L1L2Svm::new(m, 0.4)
    }

    #[test]
    fn zero_point_loss() {
        let p = problem();
        // F(0) = Σ max(0, 1)² = m.
        assert!((p.smooth(&vec![0.0; 6]) - 12.0).abs() < 1e-12);
    }

    #[test]
    fn loss_vanishes_on_large_margins() {
        let _p = problem();
        // Per-sample loss is zero when the margin exceeds 1.
        let z = [2.0, 1.5];
        let loss: f64 = z.iter().map(|&zi: &f64| (1.0 - zi).max(0.0).powi(2)).sum();
        assert_eq!(loss, 0.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = problem();
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let mut x = vec![0.0; 6];
        rng.fill_normal(&mut x);
        ops::scal(0.1, &mut x); // keep margins near the kink-free region
        let mut g = vec![0.0; 6];
        p.grad_smooth(&x, &mut g);
        let h = 1e-6;
        for j in 0..6 {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let fd = (p.smooth(&xp) - p.smooth(&xm)) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-4, "coord {j}: {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn curvature_and_lipschitz_sane() {
        let p = problem();
        let mut d = vec![0.0; 6];
        p.curvature(&[0.0; 6], &mut d);
        for j in 0..6 {
            assert!(d[j] > 0.0);
        }
        assert!(p.lipschitz_grad() > 0.0);
        assert!(p.curvature_trace() >= d.iter().cloned().fold(0.0, f64::max));
    }
}
