//! L3 parallel coordinator: leader/worker block decomposition.
//!
//! Mirrors the paper's MPI structure with threads: the leader owns the
//! iterate schedule (γ, τ, selection) and the workers own contiguous
//! column shards, computing partial residual products, block
//! best-responses and error bounds. See [`costmodel`] for how measured
//! single-core phase times are converted to the paper's 16/32-process
//! wall-clock estimates.

pub mod costmodel;
pub mod shard;
pub mod worker;

pub use costmodel::CostModel;
pub use shard::ShardPlan;
pub use worker::ParallelFpa;
