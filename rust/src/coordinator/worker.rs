//! Threaded leader/worker FPA — the paper's MPI process structure mapped
//! onto threads.
//!
//! Workers own contiguous column shards (see [`super::shard`]). One
//! iteration is two bulk-synchronous phases, exactly the communication
//! pattern of the paper's C++/MPI implementation:
//!
//! 1. **Partial products**: worker `w` computes `p_w = A_{:,w} x_w`; the
//!    leader reduces `r = Σ_w p_w − b` (the MPI allreduce of an m-vector).
//! 2. **Best-responses**: given `r`, worker `w` computes its blocks'
//!    gradients `2A_jᵀr`, best-responses and error bounds `Eᵢ`; the leader
//!    takes the global max-E, applies the greedy ρ-selection and the
//!    `γᵏ` step, and adapts τ.
//!
//! Each worker reports its measured compute time per phase; the simulated
//! P-process wall-clock uses the *max over workers* per phase plus the
//! cost-model's allreduce estimate — the standard BSP accounting. On this
//! single-core container the threads timeshare, so measured wall-clock is
//! ~serial; the simulated clock is what reproduces the paper's scaling
//! (see DESIGN.md §6).

use super::shard::ShardPlan;
use crate::algos::fpa::{FpaOptions, Surrogate};
use crate::algos::{Recorder, SolveOptions, SolveReport};
use crate::linalg::ops;
use crate::problems::LeastSquares;
use crate::select::Selector;
use crate::stepsize::Schedule;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Leader → worker commands.
enum Cmd {
    /// Compute the shard's partial product `A_{:,w} x_w`.
    Partial(Arc<Vec<f64>>),
    /// Compute block best-responses + error bounds given the residual.
    BestResponse { x: Arc<Vec<f64>>, r: Arc<Vec<f64>>, tau: f64 },
    Stop,
}

/// Worker → leader results (worker id, payloads, measured seconds).
enum Res {
    Partial(#[allow(dead_code)] usize, Vec<f64>, f64),
    Br { worker: usize, zhat: Vec<f64>, e: Vec<f64>, seconds: f64 },
}

/// Threaded parallel FPA over least-squares composite problems.
#[derive(Clone, Debug)]
pub struct ParallelFpa {
    pub workers: usize,
    pub opts: FpaOptions,
}

impl ParallelFpa {
    pub fn new(workers: usize, opts: FpaOptions) -> Self {
        assert!(workers >= 1);
        Self { workers, opts }
    }

    /// Paper defaults with `workers` threads.
    pub fn paper_defaults(workers: usize) -> Self {
        Self::new(workers, FpaOptions::default())
    }

    /// Solve; the report's `sim_time_s` uses `opts.cost_model` (set
    /// `CostModel::mpi_node(P)` to reproduce the paper's 16/32-process
    /// time axis).
    pub fn solve<P: LeastSquares + ?Sized>(&self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let m = problem.rows();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();
        let plan = ShardPlan::balanced(&layout, self.workers);
        let w_count = plan.workers();
        let label = format!("pfpa-w{}", self.workers);
        let mut recorder = Recorder::new(&label, problem, opts);

        let mut x_vec = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut d = vec![0.0; n];
        problem.curvature(&x_vec, &mut d);
        let d = Arc::new(d);
        // Same precedence as the serial `Fpa`: warm-start override, then
        // the solver's tau0, then the paper's tr(AᵀA)/2n default.
        let mut tau = opts
            .tau0
            .or(self.opts.tau0)
            .unwrap_or_else(|| problem.curvature_trace() / (2.0 * n as f64));
        let mut schedule = Schedule::new(self.opts.step.clone());
        let mut selector = Selector::new(self.opts.selection.clone());
        let surrogate = self.opts.surrogate;

        let mut v_prev = f64::INFINITY;
        let mut tau_changes = 0usize;
        let mut decrease_streak = 0usize;
        // Same τ-rule safeguards as the serial `Fpa` (kept in lockstep so
        // the parity test holds bit-for-bit in iteration count).
        let mut halve_after = self.opts.tau_halve_after;
        let mut halved_last_iter = false;
        let mut tau_safe = tau;
        let mut v_best = f64::INFINITY;
        let reduce_bytes = 8 * (m + 16);

        let (res_tx, res_rx): (Sender<Res>, Receiver<Res>) = channel();
        let mut cmd_txs: Vec<Sender<Cmd>> = Vec::with_capacity(w_count);

        let report = std::thread::scope(|scope| {
            // --- spawn workers ---
            for w in 0..w_count {
                let (tx, rx): (Sender<Cmd>, Receiver<Cmd>) = channel();
                cmd_txs.push(tx);
                let res_tx = res_tx.clone();
                let blocks = plan.blocks(w);
                let vars = plan.vars(w, &layout);
                let layout = layout.clone();
                let d = Arc::clone(&d);
                let problem: &P = problem;
                scope.spawn(move || {
                    worker_loop(w, problem, &layout, blocks, vars, &d, surrogate, rx, res_tx)
                });
            }
            recorder.setup_done();

            let mut iterations = 0;
            let mut converged = false;
            let mut r_vec = vec![0.0; m];
            let mut zhat = vec![0.0; n];
            let mut e = vec![0.0; nb];
            let mut mask = vec![false; nb];
            let mut x_best = x_vec.clone();

            for k in 0..opts.max_iters {
                iterations = k + 1;

                // --- phase 1: partial products / residual reduce ---
                let x_arc = Arc::new(x_vec.clone());
                for tx in &cmd_txs {
                    tx.send(Cmd::Partial(Arc::clone(&x_arc))).expect("worker alive");
                }
                r_vec.fill(0.0);
                let mut phase1_max = 0.0f64;
                let t_leader1 = Instant::now();
                for _ in 0..w_count {
                    match res_rx.recv().expect("worker result") {
                        Res::Partial(_, partial, secs) => {
                            ops::axpy(1.0, &partial, &mut r_vec);
                            phase1_max = phase1_max.max(secs);
                        }
                        _ => unreachable!("protocol: expected Partial"),
                    }
                }
                for (ri, bi) in r_vec.iter_mut().zip(problem.rhs()) {
                    *ri -= bi;
                }
                let f_val = ops::nrm2_sq(&r_vec);

                // --- phase 2: best-responses ---
                let r_arc = Arc::new(r_vec.clone());
                for tx in &cmd_txs {
                    tx.send(Cmd::BestResponse { x: Arc::clone(&x_arc), r: Arc::clone(&r_arc), tau })
                        .expect("worker alive");
                }
                let mut phase2_max = 0.0f64;
                for _ in 0..w_count {
                    match res_rx.recv().expect("worker result") {
                        Res::Br { worker, zhat: z_w, e: e_w, seconds } => {
                            let vars = plan.vars(worker, &layout);
                            zhat[vars.clone()].copy_from_slice(&z_w);
                            let blocks = plan.blocks(worker);
                            e[blocks.clone()].copy_from_slice(&e_w);
                            phase2_max = phase2_max.max(seconds);
                        }
                        _ => unreachable!("protocol: expected Br"),
                    }
                }
                let leader_overhead = t_leader1.elapsed().as_secs_f64() - phase1_max - phase2_max;

                // --- leader: selection, step, τ adaptation ---
                let t_serial = Instant::now();
                // V(xᵏ): both F and G at the pre-update iterate.
                let v_now = f_val + problem.reg(&x_vec);
                let gamma = schedule.gamma();
                let updated = selector.select(&e, &mut mask);
                for i in 0..nb {
                    if mask[i] {
                        for j in layout.range(i) {
                            x_vec[j] += gamma * (zhat[j] - x_vec[j]);
                        }
                    }
                }
                schedule.advance();
                if v_now < v_best {
                    v_best = v_now;
                    x_best.copy_from_slice(&x_vec);
                }
                if self.opts.tau_adapt {
                    if !v_now.is_finite() || v_now > 1e3 * v_best.abs().max(1e-12) {
                        x_vec.copy_from_slice(&x_best);
                        tau *= 4.0;
                        decrease_streak = 0;
                        halve_after = halve_after.saturating_mul(4);
                        halved_last_iter = false;
                    } else if tau_changes < self.opts.tau_max_changes {
                        if v_now >= v_prev {
                            tau = (tau * 2.0).max(tau_safe);
                            tau_changes += 1;
                            decrease_streak = 0;
                            if halved_last_iter {
                                halve_after = halve_after.saturating_mul(2).min(1 << 14);
                            }
                            halved_last_iter = false;
                        } else {
                            decrease_streak += 1;
                            if decrease_streak >= halve_after {
                                tau_safe = tau;
                                tau *= 0.5;
                                tau_changes += 1;
                                decrease_streak = 0;
                                halved_last_iter = true;
                            }
                        }
                    }
                }
                v_prev = v_now;
                let serial_s = t_serial.elapsed().as_secs_f64() + leader_overhead.max(0.0);

                // BSP time: max worker phase times are already "per
                // process"; two allreduces (residual + E-max/z exchange).
                let sim = phase1_max + phase2_max
                    + serial_s
                    + 2.0 * opts.cost_model.allreduce_s(reduce_bytes);
                recorder.add_sim_time(sim);

                recorder.note_step(gamma, tau);
                let err = recorder.record(k, &x_vec, updated);
                if recorder.reached(err) {
                    converged = true;
                    break;
                }
                if recorder.cancelled() {
                    break;
                }
                if e.iter().cloned().fold(0.0, f64::max) == 0.0 {
                    break;
                }
                if recorder.elapsed_s() > opts.max_seconds {
                    break;
                }
            }

            for tx in &cmd_txs {
                let _ = tx.send(Cmd::Stop);
            }
            let objective = problem.objective(&x_vec);
            SolveReport {
                x: x_vec.clone(),
                objective,
                iterations,
                converged,
                trace: recorder.into_trace(),
            }
        });
        report
    }
}

/// Worker event loop.
#[allow(clippy::too_many_arguments)]
fn worker_loop<P: LeastSquares + ?Sized>(
    id: usize,
    problem: &P,
    layout: &crate::problems::BlockLayout,
    blocks: std::ops::Range<usize>,
    vars: std::ops::Range<usize>,
    d: &[f64],
    surrogate: Surrogate,
    rx: Receiver<Cmd>,
    tx: Sender<Res>,
) {
    let mut v_scratch: Vec<f64> = Vec::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Partial(x) => {
                let t = Instant::now();
                let m = problem.rows();
                let mut partial = vec![0.0; m];
                for j in vars.clone() {
                    if x[j] != 0.0 {
                        problem.col_axpy(j, x[j], &mut partial);
                    }
                }
                let secs = t.elapsed().as_secs_f64();
                if tx.send(Res::Partial(id, partial, secs)).is_err() {
                    return;
                }
            }
            Cmd::BestResponse { x, r, tau } => {
                let t = Instant::now();
                let mut zhat = vec![0.0; vars.len()];
                let mut e = vec![0.0; blocks.len()];
                for (bi, i) in blocks.clone().enumerate() {
                    let rng = layout.range(i);
                    let (lo, hi) = (rng.start, rng.end);
                    let denom = match surrogate {
                        Surrogate::Linear => tau,
                        Surrogate::DiagQuadratic => d[lo] + tau,
                    };
                    v_scratch.clear();
                    for j in lo..hi {
                        let g_j = 2.0 * problem.col_dot(j, &r);
                        v_scratch.push(x[j] - g_j / denom);
                    }
                    let zlo = lo - vars.start;
                    let zhi = hi - vars.start;
                    problem.prox_block(i, &v_scratch, 1.0 / denom, &mut zhat[zlo..zhi]);
                    e[bi] = ops::dist2(&zhat[zlo..zhi], &x[lo..hi]);
                }
                let secs = t.elapsed().as_secs_f64();
                if tx.send(Res::Br { worker: id, zhat, e, seconds: secs }).is_err() {
                    return;
                }
            }
            Cmd::Stop => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::fpa::Fpa;
    use crate::algos::Solver;
    use crate::coordinator::CostModel;
    use crate::datagen::NesterovLasso;
    use crate::problems::lasso::Lasso;

    fn planted(seed: u64) -> Lasso {
        let inst = NesterovLasso::new(30, 80, 0.1, 1.0).seed(seed).generate();
        let v = inst.v_star;
        Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v)
    }

    #[test]
    fn parallel_matches_serial_fpa() {
        let p = planted(101);
        let opts = SolveOptions::default().with_max_iters(100).with_target(0.0);
        let serial = Fpa::paper_defaults(&p).solve(&p, &opts);
        let parallel = ParallelFpa::paper_defaults(4).solve(&p, &opts);
        // Same deterministic iteration; only float reduction order differs.
        assert_eq!(serial.iterations, parallel.iterations);
        let d = ops::dist2(&serial.x, &parallel.x);
        assert!(d < 1e-8, "serial and parallel iterates differ by {d}");
    }

    #[test]
    fn converges_with_various_worker_counts() {
        let p = planted(102);
        for w in [1, 2, 7] {
            let report = ParallelFpa::paper_defaults(w)
                .solve(&p, &SolveOptions::default().with_max_iters(8000).with_target(1e-4));
            assert!(
                report.trace.best_rel_err() < 1e-3,
                "w={w}: best {:.3e}",
                report.trace.best_rel_err()
            );
        }
    }

    #[test]
    fn more_workers_than_blocks_is_fine() {
        let inst = NesterovLasso::new(10, 6, 0.5, 1.0).seed(103).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let report = ParallelFpa::paper_defaults(16)
            .solve(&p, &SolveOptions::default().with_max_iters(500).with_target(1e-4));
        assert!(report.objective.is_finite());
    }

    #[test]
    fn simulated_time_scales_with_cost_model() {
        let p = planted(104);
        let base = SolveOptions::default().with_max_iters(30).with_target(0.0);
        let serial_cm = ParallelFpa::paper_defaults(2).solve(&p, &base);
        let mpi = base.with_cost_model(CostModel::mpi_node(16));
        let mpi_run = ParallelFpa::paper_defaults(2).solve(&p, &mpi);
        // With comm costs the simulated clock must be >= the no-comm one
        // per iteration on the same worker split (statistically).
        let t1 = serial_cm.trace.last().unwrap().sim_time_s;
        let t2 = mpi_run.trace.last().unwrap().sim_time_s;
        assert!(t2 > 0.0 && t1 > 0.0);
    }
}
