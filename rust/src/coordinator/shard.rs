//! Block sharding: assigning variable blocks to workers.
//!
//! Shards are contiguous block ranges balanced by variable count, matching
//! the paper's even column partition across MPI processes (column-major
//! storage makes each shard one contiguous slab of `A`).

use crate::problems::BlockLayout;

/// A plan assigning each of `N` blocks to one of `W` workers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// `bounds[w]..bounds[w+1]` are the blocks of worker `w`.
    bounds: Vec<usize>,
}

impl ShardPlan {
    /// Balance blocks across `workers` by variable count (greedy
    /// contiguous partition: each shard takes blocks until it reaches the
    /// ideal share).
    pub fn balanced(layout: &BlockLayout, workers: usize) -> Self {
        let workers = workers.max(1);
        let nb = layout.num_blocks();
        let total_vars = layout.dim();
        let ideal = total_vars as f64 / workers as f64;
        let mut bounds = Vec::with_capacity(workers + 1);
        bounds.push(0);
        let mut acc = 0usize;
        let mut next_target = ideal;
        for i in 0..nb {
            acc += layout.len(i);
            // Close the shard when reaching the target, leaving enough
            // blocks for the remaining workers.
            let shards_done = bounds.len() - 1;
            let remaining_shards = workers - shards_done;
            let remaining_blocks = nb - (i + 1);
            if shards_done < workers - 1
                && (acc as f64 >= next_target || remaining_blocks < remaining_shards)
            {
                bounds.push(i + 1);
                next_target += ideal;
            }
        }
        while bounds.len() < workers + 1 {
            bounds.push(nb);
        }
        Self { bounds }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Block range of worker `w`.
    pub fn blocks(&self, w: usize) -> std::ops::Range<usize> {
        self.bounds[w]..self.bounds[w + 1]
    }

    /// Variable range of worker `w` under `layout`.
    pub fn vars(&self, w: usize, layout: &BlockLayout) -> std::ops::Range<usize> {
        let blocks = self.blocks(w);
        if blocks.is_empty() {
            return 0..0;
        }
        layout.range(blocks.start).start..layout.range(blocks.end - 1).end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_blocks_disjointly() {
        let layout = BlockLayout::scalar(100);
        let plan = ShardPlan::balanced(&layout, 7);
        assert_eq!(plan.workers(), 7);
        let mut covered = vec![false; 100];
        for w in 0..7 {
            for b in plan.blocks(w) {
                assert!(!covered[b], "block {b} assigned twice");
                covered[b] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn balanced_within_one_block() {
        let layout = BlockLayout::scalar(1000);
        let plan = ShardPlan::balanced(&layout, 16);
        for w in 0..16 {
            let len = plan.blocks(w).len();
            assert!((62..=63).contains(&len), "worker {w} has {len} blocks");
        }
    }

    #[test]
    fn more_workers_than_blocks() {
        let layout = BlockLayout::scalar(3);
        let plan = ShardPlan::balanced(&layout, 8);
        let nonempty = (0..8).filter(|&w| !plan.blocks(w).is_empty()).count();
        assert_eq!(nonempty, 3);
        // All blocks covered exactly once.
        let total: usize = (0..8).map(|w| plan.blocks(w).len()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn variable_ranges_contiguous() {
        let layout = BlockLayout::uniform(20, 3); // 7 blocks: 3,3,3,3,3,3,2
        let plan = ShardPlan::balanced(&layout, 3);
        let mut last_end = 0;
        for w in 0..3 {
            let vr = plan.vars(w, &layout);
            assert_eq!(vr.start, last_end);
            last_end = vr.end;
        }
        assert_eq!(last_end, 20);
    }

    #[test]
    fn single_worker_takes_everything() {
        let layout = BlockLayout::uniform(10, 2);
        let plan = ShardPlan::balanced(&layout, 1);
        assert_eq!(plan.blocks(0), 0..5);
        assert_eq!(plan.vars(0, &layout), 0..10);
    }
}
