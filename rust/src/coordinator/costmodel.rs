//! Bulk-synchronous parallel cost model.
//!
//! The paper's experiments ran on a 32-core MPI node (16 or 32 processes).
//! This container has a single core, so real thread-parallel wall-clock
//! cannot show the paper's scaling. Instead, every solver separates its
//! per-iteration work into a *parallelizable* phase (block-partitioned:
//! matvecs, best-responses, error bounds) and a *serial* phase (the
//! leader's reduction: max-E selection, γ/τ updates), and the cost model
//! converts measured single-core phase times into the bulk-synchronous
//! P-process estimate:
//!
//! `T_P = T_parallel / P + T_serial + T_allreduce(P, bytes)`
//!
//! with the standard recursive-doubling allreduce estimate
//! `T_allreduce = 2·log₂(P)·(latency + bytes/bandwidth)`.
//!
//! With `procs = 1` the model is the identity (no comm, no scaling), so
//! measured and simulated times coincide — integration tests assert this.

/// Cost model parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Number of simulated processes `P`.
    pub procs: usize,
    /// Link bandwidth in bytes/second (default: Infiniband-class 5 GB/s,
    /// matching the paper's testbed interconnect).
    pub bandwidth: f64,
    /// Per-message latency in seconds (default 5 µs).
    pub latency: f64,
}

impl CostModel {
    /// Identity model: 1 process, no communication.
    pub fn serial() -> Self {
        Self { procs: 1, bandwidth: f64::INFINITY, latency: 0.0 }
    }

    /// Infiniband-class cluster node with `procs` processes (the paper's
    /// testbed: one 32-core node, 16 or 32 MPI processes).
    pub fn mpi_node(procs: usize) -> Self {
        assert!(procs >= 1);
        Self { procs, bandwidth: 5e9, latency: 5e-6 }
    }

    /// Estimated allreduce time for `bytes` across `procs` ranks
    /// (recursive doubling).
    pub fn allreduce_s(&self, bytes: usize) -> f64 {
        if self.procs <= 1 {
            return 0.0;
        }
        let rounds = (self.procs as f64).log2().ceil();
        2.0 * rounds * (self.latency + bytes as f64 / self.bandwidth)
    }

    /// Simulated wall-clock for one bulk-synchronous iteration.
    ///
    /// * `parallel_s` — measured single-core time of the block-partitioned
    ///   phase (assumed perfectly divisible across `procs`; the paper's
    ///   workloads partition columns evenly so this is accurate),
    /// * `serial_s` — measured leader-side time,
    /// * `reduce_bytes` — bytes allreduced per iteration (residual m-vector
    ///   + error-bound scalars for FPA).
    pub fn iter_time(&self, parallel_s: f64, serial_s: f64, reduce_bytes: usize) -> f64 {
        parallel_s / self.procs as f64 + serial_s + self.allreduce_s(reduce_bytes)
    }
}

impl Default for CostModel {
    fn default() -> Self {
        Self::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_is_identity() {
        let m = CostModel::serial();
        assert_eq!(m.iter_time(2.0, 0.5, 1_000_000), 2.5);
        assert_eq!(m.allreduce_s(1 << 20), 0.0);
    }

    #[test]
    fn parallel_phase_scales() {
        let m = CostModel { procs: 16, bandwidth: f64::INFINITY, latency: 0.0 };
        let t = m.iter_time(1.6, 0.1, 0);
        assert!((t - 0.2).abs() < 1e-12);
    }

    #[test]
    fn allreduce_grows_with_procs_and_bytes() {
        let m2 = CostModel::mpi_node(2);
        let m32 = CostModel::mpi_node(32);
        assert!(m32.allreduce_s(1 << 20) > m2.allreduce_s(1 << 20));
        assert!(m32.allreduce_s(1 << 20) > m32.allreduce_s(1 << 10));
        // 2 ranks, 5 GB/s, 5 us latency, 1 MB: 2*1*(5e-6 + 2.097e-4).
        let expect = 2.0 * (5e-6 + (1 << 20) as f64 / 5e9);
        assert!((m2.allreduce_s(1 << 20) - expect).abs() < 1e-12);
    }

    #[test]
    fn more_procs_never_slower_without_comm() {
        let m1 = CostModel { procs: 1, bandwidth: f64::INFINITY, latency: 0.0 };
        let m8 = CostModel { procs: 8, bandwidth: f64::INFINITY, latency: 0.0 };
        assert!(m8.iter_time(1.0, 0.1, 0) < m1.iter_time(1.0, 0.1, 0));
    }

    /// Property: with communication free (infinite bandwidth, zero
    /// latency), `iter_time` is non-increasing in `procs` for fixed work
    /// — adding processes can only shrink the parallel phase.
    #[test]
    fn iter_time_nonincreasing_in_procs_for_fixed_work() {
        for &(parallel_s, serial_s, bytes) in
            &[(1.0, 0.25, 0usize), (3.5, 0.0, 1 << 20), (0.0, 1.0, 1 << 10)]
        {
            let mut prev = f64::INFINITY;
            for procs in 1..=64 {
                let m = CostModel { procs, bandwidth: f64::INFINITY, latency: 0.0 };
                let t = m.iter_time(parallel_s, serial_s, bytes);
                assert!(
                    t <= prev + 1e-15,
                    "procs {procs}: {t} > {prev} for ({parallel_s}, {serial_s}, {bytes})"
                );
                prev = t;
            }
        }
    }

    /// Property: `allreduce_s` is monotone (non-decreasing) in bytes for
    /// any process count, and identically zero for `procs <= 1`.
    #[test]
    fn allreduce_monotone_in_bytes_and_zero_for_serial() {
        for procs in [2usize, 3, 8, 17, 32] {
            let m = CostModel::mpi_node(procs);
            let mut prev = 0.0;
            for shift in 0..24 {
                let t = m.allreduce_s(1usize << shift);
                assert!(t >= prev, "procs {procs}, bytes 2^{shift}: {t} < {prev}");
                prev = t;
            }
        }
        let serial = CostModel::mpi_node(1);
        for shift in 0..24 {
            assert_eq!(serial.allreduce_s(1usize << shift), 0.0);
        }
        assert_eq!(CostModel::serial().allreduce_s(usize::MAX >> 8), 0.0);
    }

    /// Non-power-of-two process counts round the recursive-doubling
    /// rounds *up*: P = 5 pays the same 3 rounds as P = 8.
    #[test]
    fn non_power_of_two_procs_round_doubling_rounds_up() {
        let t = |procs: usize| CostModel::mpi_node(procs).allreduce_s(1 << 20);
        assert_eq!(t(3), t(4), "ceil(log2(3)) = 2 rounds");
        assert_eq!(t(5), t(8), "ceil(log2(5)) = 3 rounds");
        assert_eq!(t(9), t(16), "ceil(log2(9)) = 4 rounds");
        assert_eq!(t(17), t(32), "ceil(log2(17)) = 5 rounds");
        assert!(t(4) < t(5), "crossing a power of two adds a round");
    }

    #[test]
    fn comm_can_dominate_small_problems() {
        // Tiny parallel work, big message: 32 procs slower than 2.
        let m2 = CostModel::mpi_node(2);
        let m32 = CostModel::mpi_node(32);
        let t2 = m2.iter_time(1e-6, 0.0, 8 << 20);
        let t32 = m32.iter_time(1e-6, 0.0, 8 << 20);
        assert!(t32 > t2);
    }
}
