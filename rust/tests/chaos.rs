//! Crash-tolerance tests for `flexa::cluster` under `flexa::chaos`:
//! seeded fault injection, backend kills, and the invariants the router
//! must hold through all of it.
//!
//! Pinned behaviors:
//! * **Replicated warm starts** — a λ-sweep's cache entry replicates to
//!   the ring successor; killing the owner mid-sweep keeps the chain
//!   warm *and bit-identical* to an uninterrupted single-node sweep.
//! * **Job failover** — a job whose backend dies re-dispatches to the
//!   successor and finishes bit-identical to the fault-free golden run,
//!   with `flexa_cluster_failovers_total` and a `failover.redispatch`
//!   span accounting for the move.
//! * **Exactly-once SSE** — killing the owner while a client streams
//!   `/events` never yields a torn frame or a duplicated event: frame
//!   ids stay strictly increasing and `finished` arrives exactly once.
//! * **Local degradation** — with every backend down the router solves
//!   the job itself and reports `backend: router-local`.
//! * **No lost jobs under chaos** — with seeded connection faults on
//!   every router→backend exchange, every accepted job still completes
//!   with the golden bits. Seeds come from `FLEXA_CHAOS` when set (CI
//!   runs two fixed seeds), with built-in defaults otherwise.

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Registry, Session, SolverSpec};
use flexa::chaos::{self, ChaosConfig};
use flexa::cluster::{
    BackendSpec, ClusterConfig, ClusterServer, HealthConfig, SpawnedCluster,
};
use flexa::http::{HttpConfig, HttpServer, SpawnedServer};
use flexa::serve::{Json, ServeConfig};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn spawn_backend() -> SpawnedServer {
    let http = HttpConfig { access_log: false, ..HttpConfig::default() };
    HttpServer::bind(
        "127.0.0.1:0",
        http,
        ServeConfig::default().with_workers(1),
        Registry::with_defaults(),
    )
    .expect("bind backend")
    .spawn()
}

fn spawn_cluster(backends: &[&SpawnedServer], config: ClusterConfig) -> SpawnedCluster {
    let specs: Vec<BackendSpec> = backends
        .iter()
        .enumerate()
        .map(|(i, s)| BackendSpec { id: format!("b{i}"), addr: s.addr().to_string() })
        .collect();
    ClusterServer::bind("127.0.0.1:0", specs, config).expect("bind cluster router").spawn()
}

/// Fast probes + short connect budget, so kills are noticed quickly.
fn fast_config() -> ClusterConfig {
    ClusterConfig {
        health: HealthConfig {
            interval: Duration::from_millis(100),
            timeout: Duration::from_millis(500),
            failure_threshold: 2,
        },
        connect_timeout: Duration::from_millis(500),
        proxy_timeout: Duration::from_secs(10),
        replicate_backoff: Duration::from_millis(100),
        access_log: false,
        ..ClusterConfig::default()
    }
}

/// Chaos seeds for the fault-injection tests: the CI harness pins one
/// via `FLEXA_CHAOS`; local runs cover two fixed defaults.
fn seeds() -> Vec<u64> {
    match std::env::var("FLEXA_CHAOS").ok().and_then(|v| v.trim().parse::<u64>().ok()) {
        Some(s) => vec![s],
        None => vec![11, 29],
    }
}

/// One `Connection: close` exchange; returns (status, body).
fn req(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\nContent-Type: application/json\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).unwrap();
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).unwrap();
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let raw = String::from_utf8(raw).expect("utf8 response");
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response head: {head}"));
    (status, body.to_string())
}

fn post_job(addr: &str, spec: &str) -> Json {
    let (status, body) = req(addr, "POST", "/v1/jobs", Some(spec));
    assert_eq!(status, 202, "POST /v1/jobs: {body}");
    Json::parse(&body).expect("valid submit response")
}

/// Submit under chaos: 503/502 refusals are the documented client
/// contract (Retry-After), so retry them; anything else is a bug.
fn post_job_retry(addr: &str, spec: &str) -> Json {
    for _ in 0..40 {
        let (status, body) = req(addr, "POST", "/v1/jobs", Some(spec));
        if status == 202 {
            return Json::parse(&body).expect("valid submit response");
        }
        assert!(status == 503 || status == 502, "unexpected submit status {status}: {body}");
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("submit kept refusing under chaos");
}

fn job_id(doc: &Json) -> u64 {
    doc.get("job").and_then(|v| v.as_f64()).expect("job id") as u64
}

fn owner_of(doc: &Json) -> String {
    doc.get("backend").and_then(|v| v.as_str()).expect("owning backend").to_string()
}

fn wait_finished(addr: &str, job: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = req(addr, "GET", &format!("/v1/jobs/{job}?x=1"), None);
        assert_eq!(status, 200, "GET /v1/jobs/{job}: {body}");
        let doc = Json::parse(&body).expect("valid status json");
        if doc.get("state").and_then(|v| v.as_str()) == Some("finished") {
            return doc;
        }
        assert!(Instant::now() < deadline, "job {job} never finished: {body}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Poll to completion tolerating transient 502/503 while a failover is
/// mid-flight under injected faults.
fn wait_finished_tolerant(addr: &str, job: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = req(addr, "GET", &format!("/v1/jobs/{job}?x=1"), None);
        if status == 200 {
            let doc = Json::parse(&body).expect("valid status json");
            if doc.get("state").and_then(|v| v.as_str()) == Some("finished") {
                return doc;
            }
        } else {
            assert!(status == 502 || status == 503, "unexpected poll status {status}: {body}");
        }
        assert!(Instant::now() < deadline, "job {job} never finished: {body}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn x_of(doc: &Json) -> Vec<f64> {
    let Some(Json::Arr(items)) = doc.get("x") else { panic!("status has no x array: {doc:?}") };
    items.iter().map(|v| v.as_f64().expect("x entries are numbers")).collect()
}

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn metric(text: &str, name: &str) -> f64 {
    text.lines()
        .find(|l| l.starts_with(name) && !l.starts_with('#'))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {name} missing in:\n{text}"))
}

fn wait_metric_at_least(addr: &str, name: &str, want: f64) -> f64 {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, text) = req(addr, "GET", "/metrics", None);
        assert_eq!(status, 200);
        let v = metric(&text, name);
        if v >= want {
            return v;
        }
        assert!(Instant::now() < deadline, "{name} never reached {want} (last {v})");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn wait_unhealthy(addr: &str, id: &str) {
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let (_, topo) = req(addr, "GET", "/v1/cluster", None);
        if topo.contains(&format!("\"id\":\"{id}\",\"addr\"")) && topo.contains("\"healthy\":false")
        {
            return;
        }
        assert!(Instant::now() < deadline, "{id} never went unhealthy: {topo}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn sweep_spec(i: usize, lambda: f64) -> String {
    format!(
        "{{\"problem\":\"lasso\",\"rows\":30,\"cols\":90,\"seed\":11,\"lambda\":{lambda},\
         \"algo\":\"fpa\",\"max_iters\":40,\"target\":0,\"warm_start\":true,\"tag\":\"sweep-{i}\"}}"
    )
}

fn plain_spec(rows: usize, cols: usize, seed: u64, iters: usize, tag: &str) -> String {
    format!(
        "{{\"problem\":\"lasso\",\"rows\":{rows},\"cols\":{cols},\"seed\":{seed},\
         \"algo\":\"fpa\",\"max_iters\":{iters},\"target\":0,\"warm_start\":false,\"tag\":\"{tag}\"}}"
    )
}

fn golden_x(rows: usize, cols: usize, seed: u64, iters: usize) -> Vec<f64> {
    Session::problem(ProblemSpec::lasso(rows, cols).with_seed(seed))
        .solver(SolverSpec::parse("fpa").unwrap())
        .options(SolveOptions::default().with_max_iters(iters).with_target(0.0))
        .run()
        .expect("golden solve")
        .report
        .x
        .clone()
}

/// Tentpole 1: the λ-sweep's warm-start entry replicates to the ring
/// successor, so killing the owner mid-sweep keeps every later λ warm —
/// and the whole chain bit-identical to an uninterrupted sweep.
#[test]
fn replicated_warm_start_survives_backend_kill() {
    let _chaos = chaos::scoped_off();
    let lambdas: Vec<f64> = (0..4).map(|i| 2.0 * 0.7f64.powi(i)).collect();

    // Golden: the same sweep straight into one backend, no cluster.
    let gold_backend = spawn_backend();
    let gold_addr = gold_backend.addr().to_string();
    let mut golden: Vec<Vec<u64>> = Vec::new();
    for (i, lambda) in lambdas.iter().enumerate() {
        let doc = post_job(&gold_addr, &sweep_spec(i, *lambda));
        let done = wait_finished(&gold_addr, job_id(&doc));
        assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
        golden.push(bits(&x_of(&done)));
    }
    gold_backend.shutdown().expect("golden backend shutdown");

    // Fault run: two backends; kill the sweep's owner after λ0 has
    // replicated to the successor.
    let a = spawn_backend();
    let b = spawn_backend();
    let cluster = spawn_cluster(&[&a, &b], fast_config());
    let addr = cluster.addr().to_string();

    let doc = post_job(&addr, &sweep_spec(0, lambdas[0]));
    let owner = owner_of(&doc);
    let done = wait_finished(&addr, job_id(&doc));
    assert_eq!(bits(&x_of(&done)), golden[0], "λ0 must match before any fault");
    wait_metric_at_least(&addr, "flexa_cluster_replications_total", 1.0);

    let (dead, alive) = if owner == "b0" { (a, b) } else { (b, a) };
    dead.shutdown().expect("owner shutdown");
    wait_unhealthy(&addr, &owner);

    for (i, lambda) in lambdas.iter().enumerate().skip(1) {
        let doc = post_job(&addr, &sweep_spec(i, *lambda));
        assert_ne!(owner_of(&doc), owner, "dead backends take no placements");
        let done = wait_finished(&addr, job_id(&doc));
        assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
        assert_eq!(
            done.get("warm_started").and_then(|v| v.as_bool()),
            Some(true),
            "λ{i} must warm-start from the replicated entry: {done:?}"
        );
        assert_eq!(
            bits(&x_of(&done)),
            golden[i],
            "λ{i} after the kill must match the uninterrupted sweep bit for bit"
        );
    }

    cluster.shutdown().expect("router shutdown");
    alive.shutdown().expect("survivor shutdown");
}

/// Tentpole 2: a job whose backend dies between submit and poll fails
/// over to the ring successor inside the poll request, finishes
/// bit-identical to the fault-free golden run, and the re-dispatch is
/// visible in `flexa_cluster_failovers_total` and a
/// `failover.redispatch` trace span.
#[test]
fn inflight_job_fails_over_and_result_matches_golden() {
    let _chaos = chaos::scoped_off();
    let golden = golden_x(25, 75, 7, 30);

    let a = spawn_backend();
    let b = spawn_backend();
    // Default (slow) probes: the kill is discovered by the failed poll,
    // not the prober — pinning the in-request failover path.
    let config = ClusterConfig {
        connect_timeout: Duration::from_millis(500),
        access_log: false,
        ..ClusterConfig::default()
    };
    let cluster = spawn_cluster(&[&a, &b], config);
    let addr = cluster.addr().to_string();

    let doc = post_job(&addr, &plain_spec(25, 75, 7, 30, "inflight"));
    let rid = job_id(&doc);
    let owner = owner_of(&doc);
    let (dead, alive) = if owner == "b0" { (a, b) } else { (b, a) };
    dead.shutdown().expect("owner shutdown");

    let done = wait_finished(&addr, rid);
    assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
    assert_eq!(
        bits(&x_of(&done)),
        bits(&golden),
        "failover re-run must reproduce the golden result bit for bit"
    );

    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert!(
        metric(&metrics, "flexa_cluster_failovers_total") >= 1.0,
        "the re-dispatch must be counted:\n{metrics}"
    );
    let (status, trace) = req(&addr, "GET", "/v1/debug/trace", None);
    assert_eq!(status, 200);
    assert!(trace.contains("failover.redispatch"), "re-dispatch must leave a span: {trace}");

    cluster.shutdown().expect("router shutdown");
    alive.shutdown().expect("survivor shutdown");
}

/// Tentpole 3 + SSE satellite: killing the owner while a client streams
/// `/events` must never tear a frame or duplicate an event. The proxy
/// resumes on the successor's deterministic replay; the client sees
/// strictly increasing frame ids, exactly one `finished`, no `retry`
/// fallback, and the final iterate still matches the golden bits.
#[test]
fn sse_stream_survives_owner_kill_without_torn_or_duplicate_frames() {
    let _chaos = chaos::scoped_off();
    let golden = golden_x(80, 400, 13, 4000);

    let a = spawn_backend();
    let b = spawn_backend();
    let cluster = spawn_cluster(&[&a, &b], fast_config());
    let addr = cluster.addr().to_string();

    let doc = post_job(&addr, &plain_spec(80, 400, 13, 4000, "stream"));
    let rid = job_id(&doc);
    let owner = owner_of(&doc);

    // Stream on a reader thread; kill the owner shortly after the
    // stream opens, while the solve is (very likely) still running.
    let stream_addr = addr.clone();
    let reader = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(&stream_addr).expect("connect stream");
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let head = format!(
            "GET /v1/jobs/{rid}/events HTTP/1.1\r\nHost: {stream_addr}\r\nConnection: close\r\n\r\n"
        );
        stream.write_all(head.as_bytes()).unwrap();
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).expect("read stream to end");
        String::from_utf8(raw).expect("utf8 stream")
    });
    std::thread::sleep(Duration::from_millis(100));
    let (dead, alive) = if owner == "b0" { (a, b) } else { (b, a) };
    dead.shutdown().expect("owner shutdown");
    let sse = reader.join().expect("stream reader");

    // Clean head, complete tail: the stream must end at a frame
    // boundary, not mid-frame.
    assert!(sse.starts_with("HTTP/1.1 200"), "{sse}");
    let body = sse.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    assert!(body.ends_with("\n\n"), "stream must end on a frame boundary:\n{body:?}");

    let events: Vec<&str> = body.lines().filter_map(|l| l.strip_prefix("event: ")).collect();
    assert_eq!(
        events.iter().filter(|e| **e == "finished").count(),
        1,
        "exactly one terminal frame: {events:?}"
    );
    assert_eq!(events.last(), Some(&"finished"), "{events:?}");
    assert!(!events.contains(&"retry"), "failover must resume, not punt: {events:?}");
    let ids: Vec<u64> =
        body.lines().filter_map(|l| l.strip_prefix("id: ")).map(|v| v.parse().unwrap()).collect();
    assert!(ids.windows(2).all(|w| w[1] > w[0]), "frame ids must be strictly increasing: {ids:?}");
    assert!(body.contains(&format!("\"job\":{rid}")), "frames carry the router id:\n{body}");

    // And the failover's result is still the golden iterate.
    let done = wait_finished(&addr, rid);
    assert_eq!(bits(&x_of(&done)), bits(&golden));

    cluster.shutdown().expect("router shutdown");
    alive.shutdown().expect("survivor shutdown");
}

/// With every backend down, the router degrades to an in-process solve:
/// 202 with `backend: router-local`, a live status/events surface, the
/// golden bits, and `flexa_cluster_local_solves_total` accounting.
#[test]
fn all_backends_down_degrades_to_router_local_solve() {
    let _chaos = chaos::scoped_off();
    let golden = golden_x(20, 60, 21, 25);

    let specs = vec![
        BackendSpec { id: "down0".into(), addr: "127.0.0.1:1".into() },
        BackendSpec { id: "down1".into(), addr: "127.0.0.1:1".into() },
    ];
    let config = ClusterConfig {
        connect_timeout: Duration::from_millis(100),
        proxy_timeout: Duration::from_millis(500),
        access_log: false,
        ..ClusterConfig::default()
    };
    let cluster = ClusterServer::bind("127.0.0.1:0", specs, config).expect("bind router").spawn();
    let addr = cluster.addr().to_string();

    let doc = post_job(&addr, &plain_spec(20, 60, 21, 25, "degraded"));
    assert_eq!(owner_of(&doc), "router-local", "{doc:?}");
    let rid = job_id(&doc);
    let done = wait_finished(&addr, rid);
    assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
    assert_eq!(done.get("solver").and_then(|v| v.as_str()), Some("local/fpa"), "{done:?}");
    assert_eq!(bits(&x_of(&done)), bits(&golden), "local degradation must match golden bits");

    let (status, sse) = req(&addr, "GET", &format!("/v1/jobs/{rid}/events"), None);
    assert_eq!(status, 200, "{sse}");
    let events: Vec<&str> = sse.lines().filter_map(|l| l.strip_prefix("event: ")).collect();
    assert_eq!(events.last(), Some(&"finished"), "{events:?}");

    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert_eq!(metric(&metrics, "flexa_cluster_local_solves_total"), 1.0, "\n{metrics}");

    cluster.shutdown().expect("router shutdown");
}

/// Seeded chaos on every router→backend exchange (connect resets, read
/// resets after the request went out, slowdowns, torn proxy streams):
/// every job a client manages to submit still completes with the golden
/// bits — at-least-once re-dispatch, never a lost or wrong result.
#[test]
fn connection_faults_never_lose_accepted_jobs() {
    for seed in seeds() {
        let golden: Vec<Vec<u64>> =
            (0..5).map(|i| bits(&golden_x(20, 60, 100 + i, 10))).collect();

        let _chaos = chaos::scoped(ChaosConfig {
            connect_reset_p: 0.30,
            read_reset_p: 0.20,
            stream_reset_p: 0.10,
            slow_p: 0.20,
            slow_ms: 5,
            store_corrupt_p: 0.0,
            ..ChaosConfig::from_seed(seed)
        });

        let a = spawn_backend();
        let b = spawn_backend();
        let config = ClusterConfig {
            connect_timeout: Duration::from_millis(500),
            proxy_timeout: Duration::from_secs(10),
            access_log: false,
            ..ClusterConfig::default()
        };
        let cluster = spawn_cluster(&[&a, &b], config);
        let addr = cluster.addr().to_string();

        for i in 0..5u64 {
            let spec = plain_spec(20, 60, 100 + i, 10, &format!("chaos-{seed}-{i}"));
            let doc = post_job_retry(&addr, &spec);
            let done = wait_finished_tolerant(&addr, job_id(&doc));
            assert_eq!(
                done.get("outcome").and_then(|v| v.as_str()),
                Some("done"),
                "seed {seed} job {i}: {done:?}"
            );
            assert_eq!(
                bits(&x_of(&done)),
                golden[i as usize],
                "seed {seed} job {i} must survive injected faults bit-exact"
            );
        }

        cluster.shutdown().expect("router shutdown");
        a.shutdown().expect("backend a shutdown");
        b.shutdown().expect("backend b shutdown");
    }
}
