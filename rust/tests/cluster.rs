//! End-to-end tests for `flexa::cluster`: a router in front of two
//! in-process `flexa::http` backends.
//!
//! Pinned behaviors:
//! * **Affinity** — an 8-point λ-sweep through the router lands every
//!   job on one backend (consistent-hash by warm-start fingerprint) and
//!   the aggregated `/metrics` shows exactly 7 cache hits.
//! * **Bit-exact split** — a job above the split threshold runs as a
//!   router-driven block-split ADMM consensus solve whose result is
//!   bit-identical to a single-node `algos::admm::Admm` run.
//! * **Drain** — draining a backend hands its warm-start snapshot to
//!   the ring successor, so the next sweep job warm-starts elsewhere.
//! * **Trace stitch** — one `x-flexa-request-id` threads the router's
//!   spans and the owning backend's in the merged `/v1/debug/trace`.
//! * **Failover** — submissions walk ring successors past a dead
//!   backend, and the prober marks it unhealthy.

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Registry, Session, SolverSpec};
use flexa::cluster::{BackendSpec, ClusterConfig, ClusterServer, HealthConfig, SpawnedCluster, SplitConfig};
use flexa::http::{HttpConfig, HttpServer, SpawnedServer};
use flexa::serve::{Json, ServeConfig};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn spawn_backend() -> SpawnedServer {
    let http = HttpConfig { access_log: false, ..HttpConfig::default() };
    HttpServer::bind("127.0.0.1:0", http, ServeConfig::default().with_workers(1), Registry::with_defaults())
        .expect("bind backend")
        .spawn()
}

fn spawn_cluster(backends: &[&SpawnedServer], config: ClusterConfig) -> SpawnedCluster {
    let specs: Vec<BackendSpec> = backends
        .iter()
        .enumerate()
        .map(|(i, s)| BackendSpec { id: format!("b{i}"), addr: s.addr().to_string() })
        .collect();
    ClusterServer::bind("127.0.0.1:0", specs, config).expect("bind cluster router").spawn()
}

fn quiet_config() -> ClusterConfig {
    ClusterConfig { access_log: false, ..ClusterConfig::default() }
}

/// One `Connection: close` exchange; returns (status, body).
fn req(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\nContent-Type: application/json\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).unwrap();
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).unwrap();
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let raw = String::from_utf8(raw).expect("utf8 response");
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response head: {head}"));
    (status, body.to_string())
}

/// POST one job through the router, asserting 202; returns the parsed
/// submit document (router job id, owning backend, optional split arity).
fn post_job(addr: &str, spec: &str) -> Json {
    let (status, body) = req(addr, "POST", "/v1/jobs", Some(spec));
    assert_eq!(status, 202, "POST /v1/jobs: {body}");
    Json::parse(&body).expect("valid submit response")
}

fn job_id(doc: &Json) -> u64 {
    doc.get("job").and_then(|v| v.as_f64()).expect("job id") as u64
}

fn wait_finished(addr: &str, job: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = req(addr, "GET", &format!("/v1/jobs/{job}?x=1"), None);
        assert_eq!(status, 200, "GET /v1/jobs/{job}: {body}");
        let doc = Json::parse(&body).expect("valid status json");
        if doc.get("state").and_then(|v| v.as_str()) == Some("finished") {
            return doc;
        }
        assert!(Instant::now() < deadline, "job {job} never finished: {body}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn x_of(doc: &Json) -> Vec<f64> {
    let Some(Json::Arr(items)) = doc.get("x") else { panic!("status has no x array: {doc:?}") };
    items.iter().map(|v| v.as_f64().expect("x entries are numbers")).collect()
}

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn metric(text: &str, name: &str) -> f64 {
    text.lines()
        .find(|l| l.starts_with(name) && !l.starts_with('#'))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {name} missing in:\n{text}"))
}

fn sweep_spec(i: usize, lambda: f64) -> String {
    format!(
        "{{\"problem\":\"lasso\",\"rows\":30,\"cols\":90,\"seed\":11,\"lambda\":{lambda},\
         \"algo\":\"fpa\",\"max_iters\":40,\"warm_start\":true,\"tag\":\"sweep-{i}\"}}"
    )
}

/// The headline acceptance scenario: every λ of a sweep shares a
/// warm-start fingerprint, so the ring sends all 8 jobs to one backend
/// and the aggregated metrics count exactly 7 cache hits (the first λ
/// is the only miss).
#[test]
fn lambda_sweep_affinity_pins_one_backend_with_seven_hits() {
    let a = spawn_backend();
    let b = spawn_backend();
    let cluster = spawn_cluster(&[&a, &b], quiet_config());
    let addr = cluster.addr().to_string();

    let (status, body) = req(&addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert!(body.contains("\"backends\":2"), "{body}");

    let mut owners = Vec::new();
    let mut last_job = 0;
    for (i, lambda) in (0..8).map(|i| (i, 2.0 * 0.7f64.powi(i))) {
        let doc = post_job(&addr, &sweep_spec(i, lambda));
        owners.push(doc.get("backend").and_then(|v| v.as_str()).expect("backend id").to_string());
        last_job = job_id(&doc);
        // Sequential: each λ must finish before the next can warm-start
        // from it.
        let done = wait_finished(&addr, last_job);
        assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
        assert_eq!(done.get("tag").and_then(|v| v.as_str()), Some(format!("sweep-{i}").as_str()));
    }
    assert!(
        owners.iter().all(|o| o == &owners[0]),
        "λ-sweep placements must share one backend, got {owners:?}"
    );

    let (status, metrics) = req(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert_eq!(metric(&metrics, "flexa_cache_hits_total"), 7.0, "\n{metrics}");
    assert_eq!(metric(&metrics, "flexa_jobs_submitted_total"), 8.0);
    assert_eq!(metric(&metrics, "flexa_cluster_jobs_routed_total"), 8.0);
    assert_eq!(metric(&metrics, "flexa_cluster_backends_total"), 2.0);
    assert_eq!(
        metric(&metrics, &format!("flexa_cluster_backend_placed_total{{backend=\"{}\"}}", owners[0])),
        8.0
    );

    // The SSE proxy forwards the full lifecycle with the router's job id.
    let (status, sse) = req(&addr, "GET", &format!("/v1/jobs/{last_job}/events"), None);
    assert_eq!(status, 200, "{sse}");
    let events: Vec<&str> = sse.lines().filter_map(|l| l.strip_prefix("event: ")).collect();
    assert_eq!(events.first(), Some(&"queued"), "{events:?}");
    assert_eq!(events.last(), Some(&"finished"), "{events:?}");
    assert!(sse.contains(&format!("\"job\":{last_job}")), "data frames carry the router id:\n{sse}");

    // Topology + router-side 404s.
    let (status, topo) = req(&addr, "GET", "/v1/cluster", None);
    assert_eq!(status, 200);
    assert!(topo.contains("\"id\":\"b0\"") && topo.contains("\"id\":\"b1\""), "{topo}");
    let (status, body) = req(&addr, "GET", "/v1/jobs/999", None);
    assert_eq!(status, 404);
    assert!(body.contains("no such job 999"), "{body}");
    let (status, _) = req(&addr, "PUT", "/v1/jobs", None);
    assert_eq!(status, 405);

    cluster.shutdown().expect("router shutdown");
    a.shutdown().expect("backend a shutdown");
    b.shutdown().expect("backend b shutdown");
}

/// A job above the split threshold runs as a router-driven consensus
/// solve across both backends — and the merged trajectory is
/// bit-identical to single-node [`flexa::algos::admm::Admm`].
#[test]
fn split_admm_over_the_cluster_is_bit_identical_to_single_node() {
    let a = spawn_backend();
    let b = spawn_backend();
    let config = ClusterConfig {
        split: SplitConfig { threshold_cols: 64, ..SplitConfig::default() },
        ..quiet_config()
    };
    let cluster = spawn_cluster(&[&a, &b], config);
    let addr = cluster.addr().to_string();

    let spec = "{\"problem\":\"lasso\",\"rows\":60,\"cols\":200,\"seed\":5,\
                \"algo\":\"admm\",\"max_iters\":6,\"target\":0,\"tag\":\"split\"}";
    let doc = post_job(&addr, spec);
    assert_eq!(doc.get("split").and_then(|v| v.as_f64()), Some(2.0), "{doc:?}");
    let job = job_id(&doc);

    let done = wait_finished(&addr, job);
    assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
    assert_eq!(done.get("solver").and_then(|v| v.as_str()), Some("admm-split/2"));
    assert_eq!(done.get("iterations").and_then(|v| v.as_f64()), Some(6.0));

    let reference = Session::problem(ProblemSpec::lasso(60, 200).with_seed(5))
        .solver(SolverSpec::parse("admm").unwrap())
        .options(SolveOptions::default().with_max_iters(6).with_target(0.0))
        .run()
        .expect("single-node admm reference");
    assert_eq!(reference.report.iterations, 6);
    assert_eq!(
        bits(&x_of(&done)),
        bits(&reference.report.x),
        "split-mode ADMM must merge to the single-node iterate bit for bit"
    );
    let objective = done.get("objective").and_then(|v| v.as_f64()).expect("objective");
    assert_eq!(objective.to_bits(), reference.report.objective.to_bits());

    // The synthesized split stream narrates every outer round.
    let (status, sse) = req(&addr, "GET", &format!("/v1/jobs/{job}/events"), None);
    assert_eq!(status, 200, "{sse}");
    let events: Vec<&str> = sse.lines().filter_map(|l| l.strip_prefix("event: ")).collect();
    assert_eq!(events.first(), Some(&"queued"), "{events:?}");
    assert!(events.contains(&"split-started"), "{events:?}");
    assert_eq!(events.iter().filter(|e| **e == "outer").count(), 6, "{events:?}");
    assert_eq!(events.last(), Some(&"finished"), "{events:?}");

    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert_eq!(metric(&metrics, "flexa_cluster_jobs_split_total"), 1.0);

    cluster.shutdown().expect("router shutdown");
    a.shutdown().expect("backend a shutdown");
    b.shutdown().expect("backend b shutdown");
}

/// Draining a backend stops new placements and hands its warm-start
/// snapshot to the ring successor: the next λ of the sweep lands on the
/// other backend and still warm-starts.
#[test]
fn drain_hands_warm_starts_to_the_successor() {
    let a = spawn_backend();
    let b = spawn_backend();
    let cluster = spawn_cluster(&[&a, &b], quiet_config());
    let addr = cluster.addr().to_string();

    let doc = post_job(&addr, &sweep_spec(0, 2.0));
    let owner = doc.get("backend").and_then(|v| v.as_str()).expect("backend id").to_string();
    wait_finished(&addr, job_id(&doc));

    let (status, body) =
        req(&addr, "POST", &format!("/v1/cluster/backends/{owner}/drain"), None);
    assert_eq!(status, 200, "{body}");
    let drained = Json::parse(&body).unwrap();
    assert_eq!(drained.get("draining").and_then(|v| v.as_bool()), Some(true));
    assert!(
        drained.get("entries").and_then(|v| v.as_f64()).unwrap_or(0.0) >= 1.0,
        "the warm sweep entry must be in the snapshot: {body}"
    );
    assert!(body.contains("\"imported\":true"), "hand-off must import on the successor: {body}");

    let (_, topo) = req(&addr, "GET", "/v1/cluster", None);
    assert!(topo.contains("\"draining\":true"), "{topo}");

    // The next λ re-places on the successor and warm-starts from the
    // handed-off iterate.
    let doc = post_job(&addr, &sweep_spec(1, 1.4));
    let successor = doc.get("backend").and_then(|v| v.as_str()).expect("backend id").to_string();
    assert_ne!(successor, owner, "draining backends take no new placements");
    let done = wait_finished(&addr, job_id(&doc));
    assert_eq!(done.get("outcome").and_then(|v| v.as_str()), Some("done"), "{done:?}");
    assert_eq!(
        done.get("warm_started").and_then(|v| v.as_bool()),
        Some(true),
        "the successor must warm-start from the handed-off snapshot: {done:?}"
    );

    // Undrain restores placements; unknown ids 404.
    let (status, body) =
        req(&addr, "DELETE", &format!("/v1/cluster/backends/{owner}/drain"), None);
    assert_eq!(status, 200, "{body}");
    let (_, topo) = req(&addr, "GET", "/v1/cluster", None);
    assert!(!topo.contains("\"draining\":true"), "{topo}");
    let (status, _) = req(&addr, "POST", "/v1/cluster/backends/ghost/drain", None);
    assert_eq!(status, 404);

    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert_eq!(metric(&metrics, "flexa_cluster_drains_total"), 1.0);

    cluster.shutdown().expect("router shutdown");
    a.shutdown().expect("backend a shutdown");
    b.shutdown().expect("backend b shutdown");
}

/// One request id threads the whole path: a submit tagged with
/// `x-flexa-request-id` shows up in the merged `/v1/debug/trace` on
/// both the router's spans (pid 0) and the owning backend's (pid ≥ 1),
/// so a cross-node trace stitches on the id alone.
#[test]
fn trace_stitches_router_and_backend_spans_by_request_id() {
    let a = spawn_backend();
    let b = spawn_backend();
    let cluster = spawn_cluster(&[&a, &b], quiet_config());
    let addr = cluster.addr().to_string();

    // POST with an explicit request id (the `req` helper has no header
    // hook, so spell the exchange out).
    let spec = sweep_spec(0, 2.0);
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let head = format!(
        "POST /v1/jobs HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\
         x-flexa-request-id: stitch-test-1\r\nContent-Length: {}\r\nContent-Type: application/json\r\n\r\n",
        spec.len()
    );
    stream.write_all(head.as_bytes()).unwrap();
    stream.write_all(spec.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let raw = String::from_utf8(raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 202"), "{raw}");
    assert!(raw.contains("x-flexa-request-id: stitch-test-1"), "router echoes the id:\n{raw}");
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    let submitted = Json::parse(body).expect("submit response");
    wait_finished(&addr, job_id(&submitted));

    let (status, trace) = req(&addr, "GET", "/v1/debug/trace", None);
    assert_eq!(status, 200, "{trace}");
    let doc = Json::parse(&trace).expect("merged trace must parse");
    let Some(Json::Arr(events)) = doc.get("traceEvents") else {
        panic!("traceEvents must be an array: {trace}");
    };
    let mut pids = std::collections::BTreeSet::new();
    for e in events {
        if e.get("args").and_then(|a| a.get("request")).and_then(|r| r.as_str())
            == Some("stitch-test-1")
        {
            pids.insert(e.get("pid").and_then(|p| p.as_f64()).expect("event pid") as u64);
        }
    }
    assert!(pids.contains(&0), "router spans must carry the request id: {trace}");
    assert!(
        pids.iter().any(|p| *p > 0),
        "a backend's spans must carry the same request id (got pids {pids:?})"
    );

    cluster.shutdown().expect("router shutdown");
    a.shutdown().expect("backend a shutdown");
    b.shutdown().expect("backend b shutdown");
}

/// Killing a backend: submissions immediately fail over along the ring,
/// the prober marks it unhealthy, and with every backend gone the router
/// answers 503 instead of hanging. (Local fallback is disabled here to
/// pin the refusal path; `tests/chaos.rs` covers the degrade-to-local
/// default.)
#[test]
fn dead_backends_fail_over_then_503() {
    let a = spawn_backend();
    let b = spawn_backend();
    let config = ClusterConfig {
        health: HealthConfig {
            interval: Duration::from_millis(100),
            timeout: Duration::from_millis(500),
            failure_threshold: 2,
        },
        local_fallback: false,
        ..quiet_config()
    };
    let cluster = spawn_cluster(&[&a, &b], config);
    let addr = cluster.addr().to_string();

    // Kill b0; placements that hash to it must shed to b1 on the spot.
    a.shutdown().expect("backend a shutdown");
    for i in 0..4 {
        let spec = format!(
            "{{\"problem\":\"lasso\",\"rows\":20,\"cols\":60,\"seed\":{},\
             \"algo\":\"fpa\",\"max_iters\":5,\"tag\":\"failover-{i}\"}}",
            40 + i
        );
        let doc = post_job(&addr, &spec);
        assert_eq!(doc.get("backend").and_then(|v| v.as_str()), Some("b1"), "{doc:?}");
        wait_finished(&addr, job_id(&doc));
    }

    // The prober flips b0 unhealthy within a few probe rounds.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, topo) = req(&addr, "GET", "/v1/cluster", None);
        if topo.contains("\"id\":\"b0\",\"addr\":") && topo.contains("\"healthy\":false") {
            break;
        }
        assert!(Instant::now() < deadline, "b0 never went unhealthy: {topo}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // With the last backend gone, submissions get a clean 503.
    b.shutdown().expect("backend b shutdown");
    let (status, body) = req(
        &addr,
        "POST",
        "/v1/jobs",
        Some("{\"problem\":\"lasso\",\"rows\":20,\"cols\":60,\"algo\":\"fpa\",\"max_iters\":5}"),
    );
    assert_eq!(status, 503, "{body}");

    cluster.shutdown().expect("router shutdown");
}
