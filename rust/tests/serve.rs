//! Integration tests for `flexa::serve`: concurrent scheduling is
//! bit-identical to serial `Session` runs (including under mid-run
//! cancellation of a subset), cancellation stops running and queued
//! jobs, deadlines expire before and during a run, the warm-start cache
//! halves (at least) repeat-solve iterations, and the bounded queue
//! applies backpressure.

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Registry, Session, SolverSpec};
use flexa::serve::{CollectServeObserver, JobEvent, JobOutcome, JobSpec, Scheduler, ServeConfig};
use std::time::Duration;

/// Bit patterns of an iterate (NaN-proof equality).
fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn lasso(seed: u64) -> ProblemSpec {
    ProblemSpec::lasso(25, 75).with_sparsity(0.1).with_seed(seed)
}

/// Poll until `f()` or the timeout elapses; returns the final value.
fn wait_until(f: impl Fn() -> bool, timeout: Duration) -> bool {
    let start = std::time::Instant::now();
    while start.elapsed() < timeout {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    f()
}

/// A job that runs long enough to be cancelled / deadline-expired
/// deterministically (hundreds of thousands of iterations).
fn long_job() -> JobSpec {
    JobSpec::new(
        ProblemSpec::lasso(40, 120).with_sparsity(0.1).with_seed(901),
        SolverSpec::parse("fpa").unwrap(),
    )
    .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0))
}

/// 32 queued jobs on 4 workers: per-job results bit-identical to the
/// same specs run serially through `Session`, regardless of completion
/// order.
#[test]
fn thirty_two_jobs_on_four_workers_match_serial_bit_for_bit() {
    let solvers =
        ["fpa", "fpa-jacobi", "fpa-rho-0.9", "fista", "ista", "grock-4", "gauss-seidel", "admm"];
    let opts = SolveOptions::default().with_max_iters(40).with_target(0.0);
    let jobs: Vec<(ProblemSpec, SolverSpec)> = (0..32)
        .map(|i| (lasso(100 + (i % 8) as u64), SolverSpec::parse(solvers[i % solvers.len()]).unwrap()))
        .collect();

    let mut serial = Vec::new();
    for (p, s) in &jobs {
        let run = Session::problem(p.clone())
            .solver(s.clone())
            .options(opts.clone())
            .run()
            .unwrap();
        serial.push(run.report.clone());
    }

    let scheduler = Scheduler::start(ServeConfig::default().with_workers(4).with_cache_bytes(0));
    for (p, s) in &jobs {
        scheduler.submit(JobSpec::new(p.clone(), s.clone()).with_opts(opts.clone()));
    }
    let results = scheduler.join();
    assert_eq!(results.len(), 32);
    // join() sorts by job id == submission order, so zip against serial.
    for (r, reference) in results.iter().zip(&serial) {
        let rep = r.report.as_ref().expect("completed job has a report");
        assert!(r.outcome.is_done(), "job {}: {:?}", r.job, r.outcome);
        assert_eq!(rep.iterations, reference.iterations, "job {}", r.job);
        assert_eq!(bits(&rep.x), bits(&reference.x), "job {}: iterate must be bit-identical", r.job);
        assert_eq!(
            rep.objective.to_bits(),
            reference.objective.to_bits(),
            "job {}: objective bits",
            r.job
        );
    }
}

/// Same setup with a subset cancelled mid-run: the cancelled jobs stop
/// early, the surviving jobs stay bit-identical to serial.
#[test]
fn surviving_jobs_match_serial_under_subset_cancellation() {
    let opts = SolveOptions::default().with_max_iters(40).with_target(0.0);
    let scheduler = Scheduler::start(ServeConfig::default().with_workers(4).with_cache_bytes(0));
    let mut handles = Vec::new();
    for i in 0..32 {
        let job = if i % 8 == 3 {
            long_job() // cancellation targets: still running (or queued) when cancelled
        } else {
            JobSpec::new(lasso(200 + i as u64), SolverSpec::parse("fpa").unwrap())
                .with_opts(opts.clone())
        };
        handles.push(scheduler.submit(job));
    }
    for (i, h) in handles.iter().enumerate() {
        if i % 8 == 3 {
            h.cancel();
        }
    }
    let results = scheduler.join();
    assert_eq!(results.len(), 32);
    for (i, r) in results.iter().enumerate() {
        if i % 8 == 3 {
            assert!(
                matches!(r.outcome, JobOutcome::Cancelled { .. }),
                "job {i} should be cancelled, got {:?}",
                r.outcome
            );
            continue;
        }
        let reference = Session::problem(lasso(200 + i as u64))
            .solver_named("fpa")
            .unwrap()
            .options(opts.clone())
            .run()
            .unwrap();
        let rep = r.report.as_ref().expect("report");
        assert_eq!(rep.iterations, reference.iterations, "job {i}");
        assert_eq!(
            bits(&rep.x),
            bits(&reference.report.x),
            "job {i}: bit-identical despite cancellations"
        );
    }
}

/// Cancelling a running job stops it at an iteration boundary.
#[test]
fn cancellation_stops_a_running_job() {
    let obs = CollectServeObserver::new();
    let scheduler = Scheduler::start_with(
        ServeConfig::default().with_workers(1).with_cache_bytes(0),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    let h = scheduler.submit(long_job());
    // Wait until it demonstrably runs (at least one iteration streamed).
    assert!(
        wait_until(
            || obs.job_events(h.id()).iter().any(|e| matches!(e, JobEvent::Iteration { .. })),
            Duration::from_secs(30),
        ),
        "job never started iterating"
    );
    h.cancel();
    let results = scheduler.join();
    match &results[0].outcome {
        JobOutcome::Cancelled { iterations } => {
            assert!(*iterations >= 1 && *iterations < 50_000_000, "{iterations}");
        }
        other => panic!("expected Cancelled, got {other:?}"),
    }
    // The partial report is still returned.
    let rep = results[0].report.as_ref().unwrap();
    assert!(!rep.converged);
    assert!(rep.objective.is_finite());
}

/// A deadline expiring mid-run stops the solve cooperatively.
#[test]
fn deadline_expires_midrun() {
    let scheduler = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
    scheduler.submit(long_job().with_deadline(Duration::from_millis(150)));
    let results = scheduler.join();
    match &results[0].outcome {
        JobOutcome::DeadlineExpired { iterations } => assert!(*iterations >= 1, "{iterations}"),
        other => panic!("expected DeadlineExpired, got {other:?}"),
    }
}

/// A deadline that elapses while the job is still queued: the job never
/// starts (no `Started` event, no report).
#[test]
fn deadline_expires_while_queued() {
    let obs = CollectServeObserver::new();
    let scheduler = Scheduler::start_with(
        ServeConfig::default().with_workers(1).with_cache_bytes(0),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    let blocker = scheduler.submit(long_job());
    let doomed = scheduler.submit(lasso_job_tiny().with_deadline(Duration::from_millis(1)));
    // Give the deadline time to lapse while the worker is busy, then
    // unblock the queue.
    std::thread::sleep(Duration::from_millis(50));
    blocker.cancel();
    let results = scheduler.join();
    let r = results.iter().find(|r| r.job == doomed.id()).unwrap();
    assert!(
        matches!(r.outcome, JobOutcome::DeadlineExpired { iterations: 0 }),
        "{:?}",
        r.outcome
    );
    assert!(r.report.is_none());
    let events = obs.job_events(doomed.id());
    assert_eq!(events.len(), 2, "queued + finished only: {events:?}");
    assert!(matches!(events[0], JobEvent::Queued { .. }));
    assert!(matches!(events[1], JobEvent::Finished { .. }));
}

fn lasso_job_tiny() -> JobSpec {
    JobSpec::new(lasso(7), SolverSpec::parse("fpa").unwrap())
        .with_opts(SolveOptions::default().with_max_iters(10).with_target(0.0))
}

/// Cache-hit equivalence: a repeat solve of the same spec hits the
/// cache, converges to the same objective, and needs at most half the
/// cold-start iterations (the acceptance bound; in practice it needs
/// ~1% of them).
#[test]
fn cache_hit_repeat_solve_converges_in_half_the_iterations() {
    let obs = CollectServeObserver::new();
    let scheduler = Scheduler::start_with(
        ServeConfig::default().with_workers(1),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    let spec = ProblemSpec::lasso(40, 120).with_sparsity(0.1).with_seed(321);
    let opts = SolveOptions::default().with_max_iters(20_000).with_target(1e-6);
    let h1 = scheduler.submit(
        JobSpec::new(spec.clone(), SolverSpec::parse("fpa").unwrap())
            .with_opts(opts.clone())
            .with_warm_start(true),
    );
    let h2 = scheduler.submit(
        JobSpec::new(spec, SolverSpec::parse("fpa").unwrap())
            .with_opts(opts)
            .with_warm_start(true),
    );
    let (results, stats) = scheduler.join_with_stats();
    assert_eq!((stats.hits, stats.misses), (1, 1), "{stats:?}");

    let probe = |id: u64| -> bool {
        obs.job_events(id)
            .iter()
            .find_map(|e| match e {
                JobEvent::CacheProbe { hit, .. } => Some(*hit),
                _ => None,
            })
            .expect("warm-start job emits a cache probe")
    };
    assert!(!probe(h1.id()), "first solve is a miss");
    assert!(probe(h2.id()), "repeat solve hits");
    // Both probes report the same fingerprint key.
    let keys: Vec<u64> = obs
        .events()
        .iter()
        .filter_map(|e| match e {
            JobEvent::CacheProbe { key, .. } => Some(*key),
            _ => None,
        })
        .collect();
    assert_eq!(keys.len(), 2);
    assert_eq!(keys[0], keys[1]);

    let (cold, warm) = (&results[0], &results[1]);
    let (cold_rep, warm_rep) = (cold.report.as_ref().unwrap(), warm.report.as_ref().unwrap());
    assert!(cold_rep.converged && warm_rep.converged);
    assert!(
        warm_rep.iterations * 2 <= cold_rep.iterations,
        "warm {} vs cold {} iterations",
        warm_rep.iterations,
        cold_rep.iterations
    );
    // Both runs stop within 1e-6 relative error of V*, so they agree to
    // ~2e-6 relative; use a small safety factor.
    let scale = cold_rep.objective.abs().max(1.0);
    assert!(
        (warm_rep.objective - cold_rep.objective).abs() <= 5e-6 * scale,
        "objectives must agree at the shared target: {} vs {}",
        warm_rep.objective,
        cold_rep.objective
    );
    match (&cold.outcome, &warm.outcome) {
        (
            JobOutcome::Done { warm_started: false, .. },
            JobOutcome::Done { warm_started: true, .. },
        ) => {}
        other => panic!("unexpected outcomes {other:?}"),
    }
}

/// The bounded queue applies backpressure: `try_submit` refuses when
/// the queue is full.
#[test]
fn bounded_queue_refuses_when_full() {
    let obs = CollectServeObserver::new();
    let scheduler = Scheduler::start_with(
        ServeConfig::default().with_workers(1).with_queue_capacity(2).with_cache_bytes(0),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    let blocker = scheduler.submit(long_job());
    // Wait until the worker has taken the blocker off the queue.
    assert!(
        wait_until(
            || obs.job_events(blocker.id()).iter().any(|e| matches!(e, JobEvent::Started { .. })),
            Duration::from_secs(30),
        ),
        "blocker never started"
    );
    let _q1 = scheduler.submit(lasso_job_tiny());
    let _q2 = scheduler.submit(lasso_job_tiny());
    assert_eq!(scheduler.queued(), 2);
    let refused = scheduler.try_submit(lasso_job_tiny().with_tag("overflow"));
    let err = refused.expect_err("queue at capacity must refuse");
    let flexa::serve::SubmitError::QueueFull(full) = err else {
        panic!("expected the QueueFull refusal")
    };
    assert_eq!(full.spec.tag, "overflow", "the spec is handed back intact");
    assert_eq!(full.capacity, 2, "the typed error names the capacity hit");
    assert_eq!(scheduler.stats().rejected, 1, "refusals are counted");
    blocker.cancel();
    let results = scheduler.join();
    assert_eq!(results.len(), 3, "blocker + two queued jobs ran; the refused one never entered");
}

/// Scheduler counters stay consistent while N jobs are cancelled
/// mid-run from another thread: at every observation
/// `finished() + queue_depth + running <= submitted` (gauges are read
/// at distinct instants, so the sum may transiently undercount but must
/// never overcount), and at quiescence the buckets add up exactly —
/// `queued + running + finished == submitted` with the gauges at zero.
#[test]
fn stats_stay_consistent_under_concurrent_cancellation() {
    let scheduler = std::sync::Arc::new(Scheduler::start(
        ServeConfig::default().with_workers(2).with_cache_bytes(0),
    ));
    // Half long-running (the cancellation targets), half tiny.
    let mut handles = Vec::new();
    for i in 0..16 {
        let job = if i % 2 == 0 {
            long_job()
        } else {
            JobSpec::new(lasso(400 + i as u64), SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(30).with_target(0.0))
        };
        handles.push(scheduler.submit(job));
    }
    let cancel_targets: Vec<_> =
        handles.iter().enumerate().filter(|(i, _)| i % 2 == 0).map(|(_, h)| h.clone()).collect();
    let canceller = std::thread::spawn(move || {
        for h in cancel_targets {
            h.cancel();
            std::thread::sleep(Duration::from_millis(3));
        }
    });
    // Observe stats live throughout the drain.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let st = scheduler.stats();
        assert_eq!(st.submitted, 16);
        assert!(
            st.finished() + st.queue_depth as u64 + st.running as u64 <= st.submitted,
            "buckets overcount: {st:?}"
        );
        if st.finished() == 16 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "jobs never drained: {st:?}");
        std::thread::sleep(Duration::from_millis(2));
    }
    canceller.join().unwrap();
    let settled = scheduler.stats();
    assert_eq!(settled.queue_depth, 0, "{settled:?}");
    assert_eq!(settled.running, 0, "{settled:?}");
    assert_eq!(
        settled.done + settled.cancelled + settled.failed + settled.deadline_expired,
        16,
        "{settled:?}"
    );
    assert_eq!(settled.cancelled, 8, "every long job was cancelled: {settled:?}");
    assert_eq!(settled.done, 8, "every tiny job completed: {settled:?}");
    let results = std::sync::Arc::try_unwrap(scheduler)
        .unwrap_or_else(|_| panic!("scheduler still shared"))
        .join();
    assert_eq!(results.len(), 16);
}

/// The warm-start cache carries the spectral-norm estimate: a repeated
/// FISTA-family job hits the cache, the hit counts as a skipped
/// power-iteration preamble (`lipschitz_reuses`), and both runs
/// converge to the shared target. (Power iteration is deterministic, so
/// the seeded L is the exact value a recomputation would produce.)
#[test]
fn warm_repeat_reuses_spectral_norm_estimate() {
    let scheduler = Scheduler::start(ServeConfig::default().with_workers(1));
    let spec = ProblemSpec::lasso(40, 120).with_sparsity(0.1).with_seed(654);
    let opts = SolveOptions::default().with_max_iters(50_000).with_target(1e-3);
    for _ in 0..2 {
        scheduler.submit(
            JobSpec::new(spec.clone(), SolverSpec::parse("fista").unwrap())
                .with_opts(opts.clone())
                .with_warm_start(true),
        );
    }
    let (results, stats) = scheduler.join_with_stats();
    assert_eq!((stats.hits, stats.misses), (1, 1), "{stats:?}");
    assert_eq!(stats.lipschitz_reuses, 1, "the hit must carry the cached L: {stats:?}");
    let (cold, warm) = (results[0].report.as_ref().unwrap(), results[1].report.as_ref().unwrap());
    assert!(cold.converged && warm.converged, "cold {} / warm {}", cold.converged, warm.converged);
    assert!(
        warm.iterations <= cold.iterations,
        "warm {} vs cold {} iterations",
        warm.iterations,
        cold.iterations
    );
}

/// Live core-budget rebalancing: a long job sharing a 4-core budget with
/// a short cohort runs at a 2-thread share while they overlap, grows to
/// the full 4 at an iteration boundary once the short job finishes, and
/// its final iterate is still bit-identical to a serial `Session` run —
/// thread counts are a pure speed knob.
#[test]
fn long_job_gains_threads_after_cohort_finishes_bit_identically() {
    use flexa::api::{FnObserver, ProblemHandle};
    use flexa::serve::{CustomProblemFn, FnServeObserver};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    let long_spec = ProblemSpec::lasso(30, 90).with_sparsity(0.1).with_seed(11);
    let long_opts = SolveOptions::default().with_max_iters(400).with_target(0.0);
    let reference = Session::problem(long_spec.clone())
        .solver_named("fpa")
        .unwrap()
        .options(long_opts.clone())
        .run()
        .unwrap();

    // Handshake: the short job's build blocks until the long job has
    // demonstrably iterated under a 2-thread share (`release_short`),
    // and the long job then blocks at one iteration boundary until the
    // short job is fully finished (`short_done`, set after the running
    // gauge decremented) — so the overlap and the post-cohort regime
    // are both pinned regardless of worker timing.
    let release_short = Arc::new(AtomicBool::new(false));
    let short_done = Arc::new(AtomicBool::new(false));
    let observer = {
        let short_done = Arc::clone(&short_done);
        FnServeObserver::new(move |e: &JobEvent| {
            // The only job that can finish while the long job spins on
            // `short_done` is the short one.
            if matches!(e, JobEvent::Finished { .. }) {
                short_done.store(true, Ordering::Relaxed);
            }
        })
    };
    let scheduler = Scheduler::start_with(
        ServeConfig::default().with_workers(2).with_cache_bytes(0).with_core_budget(4),
        Some(observer),
        Registry::with_defaults(),
    );

    let build: CustomProblemFn = {
        let release_short = Arc::clone(&release_short);
        Arc::new(move || {
            let deadline = Instant::now() + Duration::from_secs(120);
            while !release_short.load(Ordering::Relaxed) {
                assert!(Instant::now() < deadline, "long job never observed the shared budget");
                std::thread::sleep(Duration::from_millis(1));
            }
            let inst = flexa::datagen::NesterovLasso::new(12, 36, 0.1, 1.0).seed(6).generate();
            Ok(ProblemHandle::least_squares(flexa::problems::lasso::Lasso::new(
                inst.a, inst.b, 0.5,
            )))
        })
    };
    // Submitted first: one worker holds it (counted as running) while
    // its build waits, so the long job dispatches into a cohort of two.
    scheduler.submit(
        JobSpec::custom("short", build, SolverSpec::parse("fpa").unwrap())
            .with_opts(SolveOptions::default().with_max_iters(2).with_target(0.0)),
    );

    let budgets = Arc::new(Mutex::new(Vec::<usize>::new()));
    let user = {
        let budgets = Arc::clone(&budgets);
        let release_short = Arc::clone(&release_short);
        let short_done = Arc::clone(&short_done);
        FnObserver::new(move |_e| {
            // The bridge re-derives the share *before* this callback, so
            // `current_threads` is the budget the next iteration runs with.
            let threads = flexa::par::current_threads();
            budgets.lock().unwrap().push(threads);
            if !release_short.load(Ordering::Relaxed) {
                // Keep iterating until a boundary observes the 2-thread
                // share (the short job's running increment has landed),
                // then let the short job build and finish.
                if threads == 2 {
                    release_short.store(true, Ordering::Relaxed);
                }
            } else if !short_done.load(Ordering::Relaxed) {
                // Hold this boundary until the cohort is gone, so the
                // remaining iterations demonstrably run post-rebalance.
                let deadline = Instant::now() + Duration::from_secs(120);
                while !short_done.load(Ordering::Relaxed) {
                    assert!(Instant::now() < deadline, "short job never finished");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        })
    };
    let h_long = scheduler.submit(
        JobSpec::new(long_spec, SolverSpec::parse("fpa").unwrap())
            .with_opts(long_opts.with_observer(user)),
    );

    let results = scheduler.join();
    let long = results.iter().find(|r| r.job == h_long.id()).unwrap();
    assert!(long.outcome.is_done(), "{:?}", long.outcome);
    let rep = long.report.as_ref().expect("report");
    let budgets = budgets.lock().unwrap();
    assert_eq!(budgets.len(), rep.iterations, "one budget sample per iteration");
    assert!(
        budgets.contains(&2),
        "overlapping with the short job halves the 4-core budget: {budgets:?}"
    );
    assert_eq!(
        budgets.last(),
        Some(&4),
        "the freed share returns to the long job at an iteration boundary: {budgets:?}"
    );
    // The whole point: rebalancing moved threads mid-solve and not a
    // single bit of the result.
    assert_eq!(rep.iterations, reference.iterations);
    assert_eq!(bits(&rep.x), bits(&reference.report.x), "bit-identical despite rebalancing");
    assert_eq!(rep.objective.to_bits(), reference.objective.to_bits());
}
