//! Golden determinism: an identical `ProblemSpec` + `SolverSpec` + seed
//! must produce an identical `IterEvent` stream across back-to-back
//! `Session` runs — the invariant the `flexa::serve` warm-start cache's
//! fingerprint keying relies on (equal spec ⇒ equal data ⇒ equal key,
//! and replayed solves are reproducible bit for bit).
//!
//! Wall-clock fields (`time_s`, `sim_time_s`) are measurements and are
//! exempt; everything the iteration *computes* must match exactly.

use flexa::algos::SolveOptions;
use flexa::api::{CollectObserver, IterEvent, ProblemSpec, Session, SolverSpec};

fn stream(problem: &ProblemSpec, solver: &str, max_iters: usize) -> Vec<IterEvent> {
    let observer = CollectObserver::new();
    let run = Session::problem(problem.clone())
        .solver(SolverSpec::parse(solver).unwrap())
        .options(SolveOptions::default().with_max_iters(max_iters).with_target(0.0))
        .observer(observer.clone())
        .run()
        .unwrap_or_else(|e| panic!("{solver}: {e:#}"));
    assert_eq!(observer.len(), run.iterations, "{solver}: one event per iteration");
    observer.events()
}

fn assert_streams_identical(a: &[IterEvent], b: &[IterEvent], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: stream lengths");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.iter, y.iter, "{label}: iteration counter");
        assert_eq!(x.updated_blocks, y.updated_blocks, "{label} k={}: |S^k|", x.iter);
        assert_eq!(x.gamma.to_bits(), y.gamma.to_bits(), "{label} k={}: gamma", x.iter);
        assert_eq!(x.tau.to_bits(), y.tau.to_bits(), "{label} k={}: tau", x.iter);
        assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{label} k={}: V", x.iter);
        assert_eq!(x.rel_err.to_bits(), y.rel_err.to_bits(), "{label} k={}: rel_err", x.iter);
    }
}

#[test]
fn identical_lasso_sessions_emit_identical_event_streams() {
    for solver in ["fpa", "fpa-rho-0.9", "fpa-jacobi", "fista", "ista", "grock-4", "gauss-seidel"] {
        let spec = ProblemSpec::lasso(30, 90).with_sparsity(0.1).with_seed(777);
        let a = stream(&spec, solver, 60);
        let b = stream(&spec, solver, 60);
        assert_streams_identical(&a, &b, solver);
    }
}

/// The general (non-least-squares) problem path is deterministic too,
/// including NaN fields (rel_err without a known V*, gamma for solvers
/// that have none) — compared via bit patterns.
#[test]
fn identical_logreg_sessions_emit_identical_event_streams() {
    let spec = ProblemSpec::logreg(30, 20).with_seed(5);
    let a = stream(&spec, "fpa", 40);
    let b = stream(&spec, "fpa", 40);
    assert!(a.iter().all(|e| e.rel_err.is_nan()), "logreg has no planted V*");
    assert_streams_identical(&a, &b, "fpa@logreg");
}

/// Random-selection FPA is seeded: same spec ⇒ same stream.
#[test]
fn seeded_random_selection_is_reproducible() {
    let spec = ProblemSpec::lasso(30, 90).with_sparsity(0.1).with_seed(91);
    let mut solver = SolverSpec::new("fpa");
    solver.set_str_option("selection", "random:5:1234").unwrap();
    let run = || {
        let observer = CollectObserver::new();
        Session::problem(spec.clone())
            .solver(solver.clone())
            .options(SolveOptions::default().with_max_iters(50).with_target(0.0))
            .observer(observer.clone())
            .run()
            .unwrap();
        observer.events()
    };
    assert_streams_identical(&run(), &run(), "fpa random:5:1234");
}
