//! Golden determinism: an identical `ProblemSpec` + `SolverSpec` + seed
//! must produce an identical `IterEvent` stream across back-to-back
//! `Session` runs — the invariant the `flexa::serve` warm-start cache's
//! fingerprint keying relies on (equal spec ⇒ equal data ⇒ equal key,
//! and replayed solves are reproducible bit for bit).
//!
//! Wall-clock fields (`time_s`, `sim_time_s`) are measurements and are
//! exempt; everything the iteration *computes* must match exactly.

use flexa::algos::SolveOptions;
use flexa::api::{CollectObserver, IterEvent, ProblemSpec, Session, SolverSpec};
use flexa::par;
use flexa::serve::{JobEvent, JobSpec, Scheduler, ServeConfig};

fn stream(problem: &ProblemSpec, solver: &str, max_iters: usize) -> Vec<IterEvent> {
    let observer = CollectObserver::new();
    let run = Session::problem(problem.clone())
        .solver(SolverSpec::parse(solver).unwrap())
        .options(SolveOptions::default().with_max_iters(max_iters).with_target(0.0))
        .observer(observer.clone())
        .run()
        .unwrap_or_else(|e| panic!("{solver}: {e:#}"));
    assert_eq!(observer.len(), run.iterations, "{solver}: one event per iteration");
    observer.events()
}

fn assert_streams_identical(a: &[IterEvent], b: &[IterEvent], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: stream lengths");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.iter, y.iter, "{label}: iteration counter");
        assert_eq!(x.updated_blocks, y.updated_blocks, "{label} k={}: |S^k|", x.iter);
        assert_eq!(x.gamma.to_bits(), y.gamma.to_bits(), "{label} k={}: gamma", x.iter);
        assert_eq!(x.tau.to_bits(), y.tau.to_bits(), "{label} k={}: tau", x.iter);
        assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{label} k={}: V", x.iter);
        assert_eq!(x.rel_err.to_bits(), y.rel_err.to_bits(), "{label} k={}: rel_err", x.iter);
    }
}

#[test]
fn identical_lasso_sessions_emit_identical_event_streams() {
    for solver in ["fpa", "fpa-rho-0.9", "fpa-jacobi", "fista", "ista", "grock-4", "gauss-seidel"] {
        let spec = ProblemSpec::lasso(30, 90).with_sparsity(0.1).with_seed(777);
        let a = stream(&spec, solver, 60);
        let b = stream(&spec, solver, 60);
        assert_streams_identical(&a, &b, solver);
    }
}

/// The general (non-least-squares) problem path is deterministic too,
/// including NaN fields (rel_err without a known V*, gamma for solvers
/// that have none) — compared via bit patterns.
#[test]
fn identical_logreg_sessions_emit_identical_event_streams() {
    let spec = ProblemSpec::logreg(30, 20).with_seed(5);
    let a = stream(&spec, "fpa", 40);
    let b = stream(&spec, "fpa", 40);
    assert!(a.iter().all(|e| e.rel_err.is_nan()), "logreg has no planted V*");
    assert_streams_identical(&a, &b, "fpa@logreg");
}

/// The `flexa::par` contract: the kernel-thread budget is a pure speed
/// knob. The same golden streams, run under 1 and 4 kernel threads,
/// must match byte for byte — across every solver family and on a
/// problem large enough that the chunked matvec / best-response / CSC
/// paths actually engage (dense 300×1200 and the sparse logreg design).
#[test]
fn event_streams_are_bit_identical_across_thread_budgets() {
    for solver in ["fpa", "fpa-rho-0.9", "fpa-jacobi", "fista", "ista", "grock-4"] {
        let spec = ProblemSpec::lasso(300, 1200).with_sparsity(0.1).with_seed(4242);
        let s1 = par::with_threads(1, || stream(&spec, solver, 25));
        let s4 = par::with_threads(4, || stream(&spec, solver, 25));
        assert_streams_identical(&s1, &s4, &format!("{solver} (1 vs 4 threads)"));
    }
    let spec = ProblemSpec::logreg(80, 60).with_seed(7);
    let s1 = par::with_threads(1, || stream(&spec, "fpa", 30));
    let s4 = par::with_threads(4, || stream(&spec, "fpa", 30));
    assert_streams_identical(&s1, &s4, "fpa@logreg (1 vs 4 threads)");
}

/// A 16-job scheduler sweep under per-job kernel budgets of 1 vs 4
/// threads: every job's terminal objective, iterate and per-job
/// Iteration-event stream must be byte-identical. (The core-budget
/// policy may cap the 4-thread request under load — also required to
/// be invisible in the results.)
#[test]
fn scheduler_sweep_is_bit_identical_across_thread_budgets() {
    let run = |threads: usize| -> Vec<(Vec<u64>, Vec<u64>)> {
        let obs = flexa::serve::CollectServeObserver::new();
        let sched = Scheduler::start_with(
            ServeConfig::default().with_workers(4).with_cache_bytes(0).with_core_budget(64),
            Some(obs.clone()),
            flexa::api::Registry::with_defaults(),
        );
        let ids: Vec<u64> = (0..16)
            .map(|i| {
                let spec = ProblemSpec::lasso(60, 240).with_sparsity(0.1).with_seed(900 + i);
                sched
                    .submit(
                        JobSpec::new(spec, SolverSpec::parse("fpa").unwrap()).with_opts(
                            SolveOptions::default()
                                .with_max_iters(30)
                                .with_target(0.0)
                                .with_threads(threads),
                        ),
                    )
                    .id()
            })
            .collect();
        let results = sched.join();
        ids.iter()
            .map(|&id| {
                let r = results.iter().find(|r| r.job == id).expect("job result");
                let report = r.report.as_ref().expect("solve ran");
                let x_bits: Vec<u64> = report.x.iter().map(|v| v.to_bits()).collect();
                let ev_bits: Vec<u64> = obs
                    .job_events(id)
                    .iter()
                    .filter_map(|e| match e {
                        JobEvent::Iteration { event, .. } => Some(event.objective.to_bits()),
                        _ => None,
                    })
                    .collect();
                assert_eq!(ev_bits.len(), 30, "job {id}: one event per iteration");
                (x_bits, ev_bits)
            })
            .collect()
    };
    let one = run(1);
    let four = run(4);
    for (i, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(a.0, b.0, "job {i}: final iterate bits");
        assert_eq!(a.1, b.1, "job {i}: per-iteration objective bits");
    }
}

/// Random-selection FPA is seeded: same spec ⇒ same stream.
#[test]
fn seeded_random_selection_is_reproducible() {
    let spec = ProblemSpec::lasso(30, 90).with_sparsity(0.1).with_seed(91);
    let mut solver = SolverSpec::new("fpa");
    solver.set_str_option("selection", "random:5:1234").unwrap();
    let run = || {
        let observer = CollectObserver::new();
        Session::problem(spec.clone())
            .solver(solver.clone())
            .options(SolveOptions::default().with_max_iters(50).with_target(0.0))
            .observer(observer.clone())
            .run()
            .unwrap();
        observer.events()
    };
    assert_streams_identical(&run(), &run(), "fpa random:5:1234");
}
