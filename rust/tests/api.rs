//! Integration tests for the unified `flexa::api` layer: every
//! (problem × solver) registry pairing runs through the `Session` builder
//! with a streaming observer attached; registry error paths return
//! suggestions instead of panicking; runtime registration extends the
//! solver set; the trace cadence never drops the final iterate.

use flexa::algos::{SolveOptions, SolveReport, Solver};
use flexa::api::{
    CollectObserver, DynSolver, FnObserver, ProblemHandle, ProblemSpec, Registry, Session,
    SolverSpec,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Tiny spec per problem family (fast enough to run the full matrix).
fn tiny_problem(kind: &str) -> ProblemSpec {
    let base = match kind {
        "lasso" => ProblemSpec::lasso(20, 60),
        "group_lasso" => ProblemSpec::group_lasso(20, 60, 3),
        "logreg" => ProblemSpec::logreg(30, 20),
        "svm" => ProblemSpec::svm(30, 20),
        other => panic!("unknown tiny problem {other}"),
    };
    base.with_sparsity(0.1).with_seed(0xA11CE)
}

/// Solvers that require the least-squares residual structure.
fn needs_least_squares(name: &str) -> bool {
    matches!(name, "gauss-seidel" | "admm" | "pfpa")
}

/// Every (problem × solver) pairing through the session API, observer
/// attached. Structural mismatches (sequential LS baselines on logistic /
/// SVM losses) must fail with a clear error, everything else must run.
#[test]
fn every_problem_solver_pairing_runs_or_explains() {
    let problems = ["lasso", "group_lasso", "logreg", "svm"];
    let solvers = [
        "fpa",
        "fpa-jacobi",
        "fpa-linear",
        "fpa-southwell",
        "fpa-rho-0.9",
        "fista",
        "ista",
        "grock-2",
        "gauss-seidel",
        "admm",
        "pfpa",
    ];
    for problem in problems {
        for solver in solvers {
            let observer = CollectObserver::new();
            let spec = SolverSpec::parse(solver).unwrap();
            let result = Session::problem(tiny_problem(problem))
                .solver(spec.clone())
                .options(SolveOptions::default().with_max_iters(30).with_target(0.0))
                .observer(observer.clone())
                .run();
            let ls_problem = problem == "lasso" || problem == "group_lasso";
            if needs_least_squares(&spec.name) && !ls_problem {
                let err = result.expect_err(&format!("{solver} on {problem} must be rejected"));
                assert!(
                    err.to_string().contains("least-squares"),
                    "{solver} on {problem}: unhelpful error `{err}`"
                );
                continue;
            }
            let run = result.unwrap_or_else(|e| panic!("{solver} on {problem}: {e:#}"));
            assert!(
                run.objective.is_finite(),
                "{solver} on {problem}: non-finite objective"
            );
            assert_eq!(run.problem, problem, "resolved problem name");
            assert_eq!(
                observer.len(),
                run.iterations,
                "{solver} on {problem}: one event per iteration"
            );
            assert!(observer.finished(), "{solver} on {problem}: on_finish must fire");
            assert_eq!(observer.converged(), run.converged);
            assert_eq!(observer.algo(), run.solver);
            let events = observer.events();
            assert!(events.iter().all(|e| e.objective.is_finite() || !run.converged));
            assert!(
                events.windows(2).all(|w| w[1].iter == w[0].iter + 1),
                "{solver} on {problem}: iteration counter must be contiguous"
            );
            if spec.name == "fpa" || spec.name == "pfpa" {
                assert!(
                    events.iter().all(|e| e.gamma.is_finite() && e.tau.is_finite()),
                    "{solver}: FPA streams gamma and tau"
                );
                assert!(events.iter().all(|e| e.updated_blocks >= 1));
            }
        }
    }
}

/// The lasso pairing converges through the session path (not just runs).
#[test]
fn session_fpa_converges_on_planted_lasso() {
    let run = Session::problem(ProblemSpec::lasso(40, 120).with_sparsity(0.1).with_seed(11))
        .solver_named("fpa")
        .unwrap()
        .options(SolveOptions::default().with_max_iters(3000).with_target(1e-6))
        .run()
        .unwrap();
    assert!(run.converged, "best {:.3e}", run.report.trace.best_rel_err());
}

/// Unknown solver/problem names: error with nearest-name suggestion, from
/// the API layer (the CLI-layer test lives in `src/main.rs`).
#[test]
fn unknown_names_error_with_suggestions() {
    let err = Session::problem(tiny_problem("lasso"))
        .solver(SolverSpec::new("fpaa"))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown solver `fpaa`"), "{err}");
    assert!(err.contains("did you mean `fpa`"), "{err}");

    let err = Session::problem(ProblemSpec::new("lass").with_dims(10, 20))
        .solver(SolverSpec::new("fpa"))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown problem `lass`"), "{err}");
    assert!(err.contains("did you mean `lasso`"), "{err}");
    assert!(err.contains("registered:"), "{err}");
}

/// A custom solver registered at runtime is reachable by name through a
/// session with a custom registry.
#[test]
fn runtime_registered_solver_runs_through_session() {
    /// Trivial custom solver: one FISTA-style pass via the public Solver
    /// machinery, wrapped manually.
    struct HalfStepIsta;
    impl DynSolver for HalfStepIsta {
        fn name(&self) -> String {
            "half-ista".into()
        }
        fn solve_session(
            &mut self,
            problem: &ProblemHandle,
            opts: &SolveOptions,
        ) -> anyhow::Result<SolveReport> {
            let mut inner = flexa::algos::ista::Ista::default();
            Ok(match problem {
                ProblemHandle::LeastSquares(p) => inner.solve(p.as_ref(), opts),
                ProblemHandle::General(p) => inner.solve(p.as_ref(), opts),
            })
        }
    }

    let mut registry = Registry::with_defaults();
    registry.register_solver(
        "half-ista",
        "custom test solver",
        Box::new(|_spec| Ok(Box::new(HalfStepIsta))),
    );
    assert!(registry.solver_names().contains(&"half-ista".to_string()));

    let run = Session::problem(tiny_problem("lasso"))
        .solver(SolverSpec::new("half-ista"))
        .options(SolveOptions::default().with_max_iters(10).with_target(0.0))
        .registry(registry)
        .run()
        .unwrap();
    assert_eq!(run.solver, "half-ista");
    assert!(run.objective.is_finite());
}

/// `record_every > 1` thins the trace but never drops the final iterate
/// (the row time-to-accuracy summaries read), while the observer still
/// sees every iteration.
#[test]
fn sparse_trace_keeps_final_iterate_and_full_event_stream() {
    let observer = CollectObserver::new();
    let run = Session::problem(tiny_problem("lasso"))
        .solver_named("fpa")
        .unwrap()
        .options(
            SolveOptions::default()
                .with_max_iters(25)
                .with_target(0.0)
                .with_record_every(7),
        )
        .observer(observer.clone())
        .run()
        .unwrap();
    let trace = &run.report.trace;
    assert!(trace.len() < run.iterations, "cadence must thin the trace");
    assert_eq!(
        trace.last().unwrap().iter,
        run.iterations - 1,
        "final iterate must be recorded even off-cadence"
    );
    assert_eq!(observer.len(), run.iterations, "events are never thinned");
}

/// A closure observer receives the stream (the dashboard-style hookup).
#[test]
fn fn_observer_streams_through_session() {
    let count = Arc::new(AtomicUsize::new(0));
    let seen = count.clone();
    let run = Session::problem(tiny_problem("lasso"))
        .solver_named("fista")
        .unwrap()
        .options(SolveOptions::default().with_max_iters(12).with_target(0.0))
        .observer(FnObserver::new(move |e| {
            assert!(e.objective.is_finite());
            seen.fetch_add(1, Ordering::SeqCst);
        }))
        .run()
        .unwrap();
    assert_eq!(count.load(Ordering::SeqCst), run.iterations);
}

/// Pre-built problems (user data, no generator) run through the same
/// session path via `with_problem`.
#[test]
fn prebuilt_problem_handle_runs() {
    let inst = flexa::datagen::NesterovLasso::new(15, 45, 0.1, 1.0).seed(21).generate();
    let lasso = flexa::problems::lasso::Lasso::new(inst.a, inst.b, inst.c)
        .with_opt_value(inst.v_star);
    let run = Session::with_problem(ProblemHandle::least_squares(lasso))
        .solver_named("fpa")
        .unwrap()
        .options(SolveOptions::default().with_max_iters(500).with_target(1e-4))
        .run()
        .unwrap();
    assert_eq!(run.problem, "custom");
    assert!(run.report.trace.best_rel_err() < 1e-2);
}

/// Specs round-trip through the TOML renderers (the serialization path a
/// server would ship across a process boundary).
#[test]
fn specs_roundtrip_toml() {
    let p = ProblemSpec::group_lasso(30, 90, 3).with_sparsity(0.2).with_seed(5);
    assert_eq!(ProblemSpec::from_toml(&p.to_toml()).unwrap(), p);
    let s = SolverSpec::parse("fpa-rho-0.25").unwrap();
    let toml = s.to_toml();
    assert!(toml.contains("selection = \"greedy:0.25\""), "{toml}");
}
