//! End-to-end tests for `flexa::watch`: a deterministically-stalling
//! job fires a `stall` alert that resolves at terminal and is visible
//! across every surface (`/v1/alerts`, `/metrics`, the SSE `warning`
//! event, and the per-job convergence series); healthy short jobs stay
//! silent; the SLO sampler reports attainment and raises `slo-burn`
//! only for unattainable targets; series/profile retention holds under
//! concurrent finishers; and the cluster router rolls a killed backend
//! up into `backend-down` on `/v1/alerts`, `/v1/cluster` and
//! `/metrics`.

use flexa::cluster::{BackendSpec, ClusterConfig, ClusterServer, HealthConfig, SpawnedCluster};
use flexa::http::{HttpConfig, HttpServer, SpawnedServer};
use flexa::serve::{Json, ServeConfig};
use flexa::watch::DetectorConfig;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn spawn(http: HttpConfig, serve: ServeConfig) -> SpawnedServer {
    HttpServer::bind("127.0.0.1:0", http, serve, flexa::api::Registry::with_defaults())
        .expect("bind loopback server")
        .spawn()
}

fn spawn_with_slo(slo_toml: &str) -> SpawnedServer {
    let slo = flexa::watch::SloConfig::from_toml_str(slo_toml).expect("valid SLO TOML");
    HttpServer::bind_with_slo(
        "127.0.0.1:0",
        HttpConfig { access_log: false, ..HttpConfig::default() },
        ServeConfig::default().with_workers(1),
        flexa::api::Registry::with_defaults(),
        None,
        Some(slo),
    )
    .expect("bind loopback server with SLO engine")
    .spawn()
}

/// One `Connection: close` exchange; returns (status, body).
fn req(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).unwrap();
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).unwrap();
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let raw = String::from_utf8(raw).expect("utf8 response");
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response head: {head}"));
    (status, body.to_string())
}

fn post_job(addr: &str, spec: &str) -> u64 {
    let (status, body) = req(addr, "POST", "/v1/jobs", Some(spec));
    assert_eq!(status, 202, "POST /v1/jobs: {body}");
    let doc = Json::parse(&body).expect("valid submit response");
    doc.get("job").and_then(Json::as_f64).expect("job id") as u64
}

fn wait_finished(addr: &str, job: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = req(addr, "GET", &format!("/v1/jobs/{job}"), None);
        assert_eq!(status, 200, "GET /v1/jobs/{job}: {body}");
        let doc = Json::parse(&body).expect("valid status json");
        if doc.get("state").and_then(Json::as_str) == Some("finished") {
            return;
        }
        assert!(Instant::now() < deadline, "job {job} never finished: {body}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// A λ-override large enough that soft-thresholding pins `x = 0` from
/// the first iteration: the objective is bit-identically flat forever
/// (and the override drops the planted `V*`, so `rel_err` is NaN) —
/// a deterministic stall, independent of solver dynamics.
fn stall_spec() -> &'static str {
    "{\"problem\":\"lasso\",\"rows\":20,\"cols\":60,\"seed\":3,\"lambda\":1000000,\
     \"algo\":\"fpa\",\"max_iters\":40,\"target\":0,\"tag\":\"stall\"}"
}

fn healthy_spec(i: usize) -> String {
    format!(
        "{{\"problem\":\"lasso\",\"rows\":25,\"cols\":75,\"seed\":7,\"algo\":\"fpa\",\
         \"max_iters\":40,\"target\":0,\"tag\":\"watch-{i}\"}}"
    )
}

/// First sample whose series starts with `prefix` (handles labels).
fn labeled_sample(text: &str, prefix: &str) -> f64 {
    text.lines()
        .find(|l| l.starts_with(prefix) && !l.starts_with('#'))
        .and_then(|l| l.rsplit_once(' '))
        .and_then(|(_, v)| v.trim().parse().ok())
        .unwrap_or_else(|| panic!("no `{prefix}` sample in:\n{text}"))
}

fn alerts_of<'a>(doc: &'a Json, key: &str) -> &'a [Json] {
    match doc.get(key) {
        Some(Json::Arr(items)) => items,
        other => panic!("`{key}` must be an array, got {other:?}"),
    }
}

/// Tentpole acceptance: the deterministic stall fires exactly one
/// `stall` alert, visible while firing nowhere (the job is too fast)
/// but pinned in `recent` after terminal resolution, counted in
/// `/metrics`, replayed as an SSE `warning` event, and the convergence
/// series serves the whole trajectory with NaN `rel_err` as `null`.
#[test]
fn stalling_job_fires_stall_across_all_surfaces() {
    let serve = ServeConfig::default()
        .with_workers(1)
        .with_watch(DetectorConfig { stall_window: 5, ..DetectorConfig::default() });
    let server = spawn(HttpConfig { access_log: false, ..HttpConfig::default() }, serve);
    let addr = server.addr().to_string();
    let job = post_job(&addr, stall_spec());
    wait_finished(&addr, job);

    // /v1/alerts: the stall is resolved (terminal resolves the scope)
    // and sits in `recent` with both timestamps.
    let (status, body) = req(&addr, "GET", "/v1/alerts", None);
    assert_eq!(status, 200, "{body}");
    let doc = Json::parse(&body).expect("alerts JSON must parse");
    let scope = format!("job:{job}");
    assert!(
        !alerts_of(&doc, "active")
            .iter()
            .any(|a| a.get("scope").and_then(Json::as_str) == Some(scope.as_str())),
        "terminal must resolve the job's alerts: {body}"
    );
    let stall = alerts_of(&doc, "recent")
        .iter()
        .find(|a| {
            a.get("kind").and_then(Json::as_str) == Some("stall")
                && a.get("scope").and_then(Json::as_str) == Some(scope.as_str())
        })
        .unwrap_or_else(|| panic!("no resolved stall for {scope} in recent: {body}"));
    assert!(stall.get("resolved_us").and_then(Json::as_f64).is_some(), "{body}");
    assert!(stall.get("since_us").and_then(Json::as_f64).is_some(), "{body}");
    let message = stall.get("message").and_then(Json::as_str).expect("message");
    assert!(message.contains("iteration"), "message names the iteration: {message}");

    // /metrics: monotone total counted, nothing left active.
    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert!(labeled_sample(&metrics, "flexa_alerts_total{kind=\"stall\"}") >= 1.0, "{metrics}");
    assert_eq!(labeled_sample(&metrics, "flexa_alerts_active{kind=\"stall\"}"), 0.0, "{metrics}");
    assert!(metrics.contains("# TYPE flexa_alerts_total counter"), "{metrics}");
    assert!(metrics.contains("# TYPE flexa_alerts_active gauge"), "{metrics}");

    // SSE replay carries the warning edge (firing, then resolution).
    let (status, sse) = req(&addr, "GET", &format!("/v1/jobs/{job}/events"), None);
    assert_eq!(status, 200);
    assert!(sse.contains("event: warning"), "no warning event in SSE replay:\n{sse}");
    assert!(sse.contains("\"kind\":\"stall\""), "{sse}");
    assert!(sse.contains("\"resolved\":false"), "the firing edge streams: {sse}");

    // Convergence series: whole trajectory recorded, NaN rel_err (the
    // λ-override drops V*) rendered as null, document fully parseable.
    let (status, conv) = req(&addr, "GET", &format!("/v1/jobs/{job}/convergence"), None);
    assert_eq!(status, 200, "{conv}");
    let series = Json::parse(&conv).expect("convergence JSON must parse");
    assert_eq!(series.get("job").and_then(Json::as_f64), Some(job as f64));
    assert_eq!(series.get("state").and_then(Json::as_str), Some("done"), "{conv}");
    assert_eq!(series.get("solver").and_then(Json::as_str), Some("fpa"), "{conv}");
    assert_eq!(series.get("recorded").and_then(Json::as_f64), Some(40.0), "{conv}");
    assert!(conv.contains("\"rel_err\":null"), "NaN must render as null: {conv}");
    let Some(Json::Arr(points)) = series.get("points") else { panic!("{conv}") };
    assert!(!points.is_empty(), "{conv}");
    for p in points {
        assert!(p.get("objective").and_then(Json::as_f64).is_some(), "{conv}");
        assert!(p.get("iter").and_then(Json::as_f64).is_some(), "{conv}");
    }
    server.shutdown().expect("clean shutdown");
}

/// Healthy fixed-budget jobs (40 iterations, default 25-iteration stall
/// window needing ≥ 50 iterations) never alert: both alert lists stay
/// empty and every per-kind counter reads zero.
#[test]
fn healthy_short_jobs_raise_no_alerts() {
    let server = spawn(
        HttpConfig { access_log: false, ..HttpConfig::default() },
        ServeConfig::default().with_workers(2),
    );
    let addr = server.addr().to_string();
    let jobs: Vec<u64> = (0..3).map(|i| post_job(&addr, &healthy_spec(i))).collect();
    for job in &jobs {
        wait_finished(&addr, *job);
    }

    let (status, body) = req(&addr, "GET", "/v1/alerts", None);
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("alerts JSON must parse");
    assert!(alerts_of(&doc, "active").is_empty(), "{body}");
    assert!(alerts_of(&doc, "recent").is_empty(), "{body}");

    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    for kind in ["stall", "divergence", "deadline-risk", "slo-burn"] {
        assert_eq!(
            labeled_sample(&metrics, &format!("flexa_alerts_total{{kind=\"{kind}\"}}")),
            0.0,
            "{metrics}"
        );
    }

    // The healthy job's series is still served, with finite rel_err
    // (the planted V* survives — no λ override).
    let (status, conv) = req(&addr, "GET", &format!("/v1/jobs/{}/convergence", jobs[0]), None);
    assert_eq!(status, 200);
    let series = Json::parse(&conv).expect("convergence JSON must parse");
    assert_eq!(series.get("recorded").and_then(Json::as_f64), Some(40.0), "{conv}");
    let last = series.get("last").expect("live frontier present");
    assert!(last.get("rel_err").and_then(Json::as_f64).is_some(), "{conv}");
    server.shutdown().expect("clean shutdown");
}

/// Endpoint contract: unknown job → 404 with a JSON error; wrong
/// method → 405; `/v1/slo` without `--slo` reports unconfigured.
#[test]
fn convergence_and_slo_endpoint_contracts() {
    let server = spawn(
        HttpConfig { access_log: false, ..HttpConfig::default() },
        ServeConfig::default().with_workers(1),
    );
    let addr = server.addr().to_string();
    let (status, body) = req(&addr, "GET", "/v1/jobs/99999/convergence", None);
    assert_eq!(status, 404, "{body}");
    let (status, _) = req(&addr, "POST", "/v1/jobs/1/convergence", Some("{}"));
    assert_eq!(status, 405);
    let (status, _) = req(&addr, "DELETE", "/v1/alerts", None);
    assert_eq!(status, 405);
    let (status, body) = req(&addr, "GET", "/v1/slo", None);
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("slo JSON must parse");
    assert_eq!(doc.get("configured").and_then(Json::as_bool), Some(false), "{body}");
    server.shutdown().expect("clean shutdown");
}

/// Generous SLO targets: the sampler populates `/v1/slo` with all
/// three targets meeting their objectives, and no `slo-burn` fires.
#[test]
fn slo_sampler_reports_attainment_without_burning() {
    let server = spawn_with_slo(
        "[slo]\nwindow_seconds = 60\nsample_interval_ms = 25\nburn_alert_threshold = 10\n\
         [slo.service]\np99_ms = 60000\nobjective = 0.5\n\
         [slo.shed]\nmax_rate = 0.99\n\
         [slo.errors]\nmax_rate = 0.99\n",
    );
    let addr = server.addr().to_string();
    for i in 0..3 {
        let job = post_job(&addr, &healthy_spec(i));
        wait_finished(&addr, job);
    }
    // Let the 25 ms sampler take enough snapshots to leave the vacuous
    // (< 2 samples) regime and observe the finished jobs.
    let deadline = Instant::now() + Duration::from_secs(30);
    let doc = loop {
        let (status, body) = req(&addr, "GET", "/v1/slo", None);
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).expect("slo JSON must parse");
        let samples = doc.get("samples").and_then(Json::as_f64).unwrap_or(0.0);
        let events: f64 = match doc.get("targets") {
            Some(Json::Arr(ts)) => {
                ts.iter().filter_map(|t| t.get("events").and_then(Json::as_f64)).sum()
            }
            _ => 0.0,
        };
        if samples >= 2.0 && events > 0.0 {
            break doc;
        }
        assert!(Instant::now() < deadline, "sampler never observed traffic: {body}");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert_eq!(doc.get("configured").and_then(Json::as_bool), Some(true));
    assert_eq!(doc.get("window_seconds").and_then(Json::as_f64), Some(60.0));
    let Some(Json::Arr(targets)) = doc.get("targets") else { panic!("targets array") };
    let names: Vec<&str> = targets.iter().filter_map(|t| t.get("name").and_then(Json::as_str)).collect();
    assert_eq!(names, ["service_latency", "shed_rate", "error_rate"], "{names:?}");
    for t in targets {
        let name = t.get("name").and_then(Json::as_str).unwrap();
        assert_eq!(t.get("meeting").and_then(Json::as_bool), Some(true), "{name} not meeting");
        let burn = t.get("burn_rate").and_then(Json::as_f64).unwrap_or(f64::NAN);
        assert!(burn <= 10.0, "{name} burn {burn} above threshold");
    }
    let (_, alerts) = req(&addr, "GET", "/v1/alerts", None);
    assert!(!alerts.contains("\"kind\":\"slo-burn\""), "{alerts}");
    server.shutdown().expect("clean shutdown");
}

/// An unattainable latency objective (p99 ≤ 1 µs): every served job is
/// a bad event, the burn rate explodes past the threshold, and the
/// sampler raises an `slo-burn` alert scoped to the target.
#[test]
fn impossible_latency_slo_fires_burn_alert() {
    let server = spawn_with_slo(
        "[slo]\nwindow_seconds = 60\nsample_interval_ms = 25\nburn_alert_threshold = 1.0\n\
         [slo.service]\np99_ms = 0.001\nobjective = 0.5\n",
    );
    let addr = server.addr().to_string();
    let job = post_job(&addr, &healthy_spec(0));
    wait_finished(&addr, job);

    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = req(&addr, "GET", "/v1/alerts", None);
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).expect("alerts JSON must parse");
        let fired = alerts_of(&doc, "active").iter().any(|a| {
            a.get("kind").and_then(Json::as_str) == Some("slo-burn")
                && a.get("scope").and_then(Json::as_str) == Some("slo:service_latency")
        });
        if fired {
            break;
        }
        assert!(Instant::now() < deadline, "slo-burn never fired: {body}");
        std::thread::sleep(Duration::from_millis(20));
    }
    let (_, slo) = req(&addr, "GET", "/v1/slo", None);
    let doc = Json::parse(&slo).expect("slo JSON must parse");
    let Some(Json::Arr(targets)) = doc.get("targets") else { panic!("{slo}") };
    let svc = targets
        .iter()
        .find(|t| t.get("name").and_then(Json::as_str) == Some("service_latency"))
        .unwrap_or_else(|| panic!("{slo}"));
    assert_eq!(svc.get("meeting").and_then(Json::as_bool), Some(false), "{slo}");
    assert!(svc.get("burn_rate").and_then(Json::as_f64).unwrap_or(0.0) > 1.0, "{slo}");
    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert!(labeled_sample(&metrics, "flexa_alerts_total{kind=\"slo-burn\"}") >= 1.0, "{metrics}");
    assert!(labeled_sample(&metrics, "flexa_alerts_active{kind=\"slo-burn\"}") >= 1.0, "{metrics}");
    server.shutdown().expect("clean shutdown");
}

/// Retention under concurrent finishers (the scheduler's worker pool in
/// miniature): 4 threads drive disjoint job ids through enqueue →
/// iterate → terminal against one shared `JobWatch` + `ProfileStore`;
/// both stores end bounded by retention with no lost updates or panics.
#[test]
fn series_and_profile_stores_prune_under_concurrent_finishers() {
    use flexa::obs::ProfileStore;
    use flexa::watch::JobWatch;
    use std::sync::Arc;

    const RETENTION: usize = 8;
    const THREADS: u64 = 4;
    const JOBS_PER_THREAD: u64 = 50;
    let watch = Arc::new(JobWatch::new(RETENTION, DetectorConfig::default()));
    let profiles = Arc::new(ProfileStore::new(RETENTION));

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let watch = Arc::clone(&watch);
            let profiles = Arc::clone(&profiles);
            std::thread::spawn(move || {
                for i in 0..JOBS_PER_THREAD {
                    let id = t * 1000 + i;
                    watch.enqueued(id, "default", None, 0.0);
                    profiles.enqueued(id, "default", flexa::obs::now_us());
                    watch.started(id, "fpa");
                    for iter in 0..6usize {
                        let event = flexa::api::IterEvent {
                            iter,
                            gamma: 0.9,
                            tau: f64::NAN,
                            updated_blocks: 4,
                            objective: 10.0 - iter as f64,
                            rel_err: f64::NAN,
                            time_s: iter as f64 * 1e-4,
                            sim_time_s: 0.0,
                        };
                        watch.observe(id, &event);
                        profiles.with(id, |p| p.add_iteration(100, 1));
                    }
                    let now = flexa::obs::now_us();
                    watch.terminal(id, "done", now);
                    profiles.terminal(id, "done", now);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("finisher thread");
    }

    let mut series_kept = 0usize;
    let mut profiles_kept = 0usize;
    for t in 0..THREADS {
        for i in 0..JOBS_PER_THREAD {
            let id = t * 1000 + i;
            if let Some(snap) = watch.series.snapshot(id) {
                series_kept += 1;
                assert_eq!(snap.state, "done", "job {id}");
                assert_eq!(snap.recorded, 6, "job {id}");
            }
            if let Some(p) = profiles.get(id) {
                profiles_kept += 1;
                assert_eq!(p.iterations.count, 6, "job {id}");
            }
        }
    }
    assert!(
        (1..=RETENTION).contains(&series_kept),
        "series retention violated: {series_kept} kept"
    );
    assert!(
        (1..=RETENTION).contains(&profiles_kept),
        "profile retention violated: {profiles_kept} kept"
    );
    // Nothing lingers in the alert store either: every job resolved.
    for (_, _, active) in watch.alerts.counts() {
        assert_eq!(active, 0);
    }
}

/// Cluster rollup acceptance: killing a backend drives `backend-down`
/// onto the router's `/v1/alerts`, into the `/v1/cluster` topology
/// (which also embeds the healthy backend's alert + SLO documents),
/// and into the aggregated `/metrics`.
#[test]
fn killed_backend_rolls_up_backend_down_alert() {
    let a = {
        let http = HttpConfig { access_log: false, ..HttpConfig::default() };
        HttpServer::bind("127.0.0.1:0", http, ServeConfig::default().with_workers(1), flexa::api::Registry::with_defaults())
            .expect("bind backend a")
            .spawn()
    };
    let b = {
        let http = HttpConfig { access_log: false, ..HttpConfig::default() };
        HttpServer::bind("127.0.0.1:0", http, ServeConfig::default().with_workers(1), flexa::api::Registry::with_defaults())
            .expect("bind backend b")
            .spawn()
    };
    let specs = vec![
        BackendSpec { id: "b0".into(), addr: a.addr().to_string() },
        BackendSpec { id: "b1".into(), addr: b.addr().to_string() },
    ];
    let config = ClusterConfig {
        access_log: false,
        health: HealthConfig {
            interval: Duration::from_millis(100),
            timeout: Duration::from_millis(500),
            failure_threshold: 2,
        },
        ..ClusterConfig::default()
    };
    let cluster: SpawnedCluster =
        ClusterServer::bind("127.0.0.1:0", specs, config).expect("bind cluster router").spawn();
    let addr = cluster.addr().to_string();

    a.shutdown().expect("backend a shutdown");

    // Prober (~2 × 100 ms) flips b0 unhealthy; the 500 ms watch sweep
    // then raises the alert.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (status, body) = req(&addr, "GET", "/v1/alerts", None);
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).expect("cluster alerts JSON must parse");
        let down = alerts_of(&doc, "active").iter().any(|al| {
            al.get("kind").and_then(Json::as_str) == Some("backend-down")
                && al.get("scope").and_then(Json::as_str) == Some("backend:b0")
        });
        if down {
            break;
        }
        assert!(Instant::now() < deadline, "backend-down never fired: {body}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // Topology rollup: router-level alerts plus the healthy backend's
    // embedded alert/SLO documents, all inside one parseable document.
    let (status, topo) = req(&addr, "GET", "/v1/cluster", None);
    assert_eq!(status, 200, "{topo}");
    let doc = Json::parse(&topo).expect("topology JSON must parse");
    assert!(topo.contains("\"kind\":\"backend-down\""), "{topo}");
    assert!(topo.contains("\"transitions\":"), "{topo}");
    assert!(
        topo.contains("\"slo\":{\"configured\":false}"),
        "healthy backend's SLO doc must be embedded: {topo}"
    );
    let Some(Json::Arr(backends)) = doc.get("backends") else { panic!("{topo}") };
    let b1 = backends
        .iter()
        .find(|x| x.get("id").and_then(Json::as_str) == Some("b1"))
        .unwrap_or_else(|| panic!("{topo}"));
    assert_eq!(b1.get("healthy").and_then(Json::as_bool), Some(true), "{topo}");
    assert!(b1.get("alerts").is_some(), "healthy backend embeds its alerts: {topo}");

    let (_, metrics) = req(&addr, "GET", "/metrics", None);
    assert!(
        labeled_sample(&metrics, "flexa_cluster_alerts_total{kind=\"backend-down\"}") >= 1.0,
        "{metrics}"
    );
    assert!(
        labeled_sample(&metrics, "flexa_cluster_alerts_active{kind=\"backend-down\"}") >= 1.0,
        "{metrics}"
    );
    assert!(metrics.contains("# TYPE flexa_cluster_alerts_total counter"), "{metrics}");

    cluster.shutdown().expect("router shutdown");
    b.shutdown().expect("backend b shutdown");
}
