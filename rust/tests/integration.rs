//! Cross-module integration tests: datagen → problems → every solver →
//! metrics, plus the threaded coordinator and the config/CLI plumbing.

use flexa::algos::admm::Admm;
use flexa::algos::fista::Fista;
use flexa::algos::fpa::Fpa;
use flexa::algos::gauss_seidel::GaussSeidel;
use flexa::algos::grock::Grock;
use flexa::algos::{SolveOptions, Solver};
use flexa::config::ExperimentConfig;
use flexa::coordinator::{CostModel, ParallelFpa};
use flexa::datagen::NesterovLasso;
use flexa::linalg::ops;
use flexa::metrics::{read_series_csv, write_trace_csv};
use flexa::problems::lasso::Lasso;
use flexa::problems::CompositeProblem;
use flexa::select::SelectionRule;

fn planted(m: usize, n: usize, sp: f64, seed: u64) -> Lasso {
    let inst = NesterovLasso::new(m, n, sp, 1.0).seed(seed).generate();
    let v = inst.v_star;
    Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v)
}

/// Every solver reaches at least a modest accuracy on the same planted
/// instance, and all agree on the final objective within tolerance.
#[test]
fn all_solvers_agree_on_planted_instance() {
    let p = planted(60, 200, 0.1, 301);
    let opts = SolveOptions::default().with_max_iters(6000).with_target(1e-5);

    let fpa = Fpa::paper_defaults(&p).solve(&p, &opts);
    let fista = Fista::default().solve(&p, &opts);
    let gs = GaussSeidel::default().solve(&p, &opts);
    let admm = Admm::default().solve(&p, &opts);
    let grock1 = Grock::new(1).solve(&p, &opts);

    for (name, r) in [
        ("fpa", &fpa),
        ("fista", &fista),
        ("gs", &gs),
        ("admm", &admm),
        ("grock1", &grock1),
    ] {
        assert!(
            r.trace.best_rel_err() < 1e-3,
            "{name}: best rel err {:.3e}",
            r.trace.best_rel_err()
        );
    }
    // Objectives agree to the loosest solver tolerance.
    let v = p.opt_value().unwrap();
    for r in [&fpa, &fista, &gs, &admm, &grock1] {
        assert!((r.objective - v).abs() / v < 2e-3);
    }
}

/// The solutions (not just values) agree: Lasso here has a unique
/// minimizer with high probability.
#[test]
fn solutions_coincide_across_methods() {
    let p = planted(50, 150, 0.08, 302);
    let opts = SolveOptions::default().with_max_iters(20000).with_target(1e-9);
    let x_fpa = Fpa::paper_defaults(&p).solve(&p, &opts).x;
    let x_gs = GaussSeidel::default().solve(&p, &opts).x;
    let d = ops::dist2(&x_fpa, &x_gs) / ops::nrm2(&x_gs).max(1.0);
    assert!(d < 1e-3, "FPA and GS solutions differ by {d}");
}

/// Threaded coordinator matches the serial solver and respects the cost
/// model.
#[test]
fn coordinator_end_to_end() {
    let p = planted(40, 120, 0.1, 303);
    let opts = SolveOptions::default()
        .with_max_iters(500)
        .with_target(1e-5)
        .with_cost_model(CostModel::mpi_node(16));
    let serial = Fpa::paper_defaults(&p).solve(&p, &opts);
    let par = ParallelFpa::paper_defaults(3).solve(&p, &opts);
    assert_eq!(serial.iterations, par.iterations);
    assert!(ops::dist2(&serial.x, &par.x) < 1e-8);
    // Simulated clock populated and positive.
    let last = par.trace.last().unwrap();
    assert!(last.sim_time_s > 0.0);
}

/// Traces round-trip through CSV and time_to_rel_err is monotone in the
/// target.
#[test]
fn metrics_roundtrip_and_monotonicity() {
    let p = planted(40, 120, 0.1, 304);
    let report = Fpa::paper_defaults(&p)
        .solve(&p, &SolveOptions::default().with_max_iters(2000).with_target(1e-6));
    let dir = std::env::temp_dir().join("flexa_integration");
    let path = dir.join("fpa.csv");
    write_trace_csv(&path, &report.trace).unwrap();
    let back = read_series_csv(&path).unwrap();
    assert_eq!(back.records.len(), report.trace.records.len());
    let t3 = back.time_to_rel_err(1e-3, false);
    let t5 = back.time_to_rel_err(1e-5, false);
    if let (Some(a), Some(b)) = (t3, t5) {
        assert!(a <= b, "tighter target cannot be reached earlier");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Experiment configs drive solver construction end-to-end — through the
/// session API, exactly as the CLI `experiment` subcommand does.
#[test]
fn config_to_solver_pipeline() {
    let cfg = ExperimentConfig::from_toml(
        r#"
        name = "itest"
        seed = 99
        algos = ["fpa"]
        [problem]
        rows = 40
        cols = 120
        sparsity = 0.1
        c = 1.0
        [algo.fpa]
        rho = 0.7
        "#,
    )
    .unwrap();
    let specs = cfg.solver_specs().unwrap();
    assert_eq!(
        specs[0].selection,
        Some(SelectionRule::GreedyRho { rho: 0.7 }),
        "config rho must reach the solver spec"
    );
    let run = flexa::api::Session::problem(cfg.problem.to_spec(cfg.seed))
        .solver(specs[0].clone())
        .options(SolveOptions::default().with_max_iters(2000))
        .run()
        .unwrap();
    assert_eq!(run.solver, "fpa(rho=0.7)");
    assert!(run.report.trace.best_rel_err() < 1e-3);
}

/// GRock's guard fires on dense problems with large P (the failure mode
/// the paper predicts), while FPA keeps making progress.
#[test]
fn grock_unstable_where_fpa_is_stable() {
    // Dense solution: correlated active set.
    let p = planted(40, 100, 0.5, 305);
    let opts = SolveOptions::default().with_max_iters(3000).with_target(1e-5);
    let grock = Grock::new(32).solve(&p, &opts);
    let fpa = Fpa::paper_defaults(&p).solve(&p, &opts);
    assert!(
        fpa.trace.best_rel_err() < grock.trace.best_rel_err() * 1.01,
        "fpa {:.3e} vs grock {:.3e}",
        fpa.trace.best_rel_err(),
        grock.trace.best_rel_err()
    );
}

/// Larger planted instances: sanity-check the medium-scale path used by
/// the figure regenerators (kept small enough for CI).
#[test]
fn medium_scale_smoke() {
    let p = planted(300, 1500, 0.1, 306);
    let opts = SolveOptions {
        max_iters: 1500,
        max_seconds: 60.0,
        target_rel_err: 1e-4,
        ..Default::default()
    };
    let fpa = Fpa::paper_defaults(&p).solve(&p, &opts);
    assert!(
        fpa.trace.best_rel_err() < 1e-3,
        "best {:.3e}",
        fpa.trace.best_rel_err()
    );
}
