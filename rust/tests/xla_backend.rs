//! Three-layer integration: AOT artifacts (L1 Pallas + L2 JAX) executed
//! via PJRT from Rust (L3), checked against the native Rust solver.
//!
//! Requires the `xla` cargo feature (PJRT bindings exist only in the
//! project's build image) and `make artifacts`; every test skips (passes
//! vacuously) when the artifact directory is missing so plain
//! `cargo test --features xla` still works.
#![cfg(feature = "xla")]

use flexa::algos::{fpa::Fpa, SolveOptions, Solver};
use flexa::datagen::NesterovLasso;
use flexa::linalg::ops;
use flexa::problems::lasso::Lasso;
use flexa::problems::{CompositeProblem, LeastSquares};
use flexa::runtime::{artifacts_available, Engine, XlaFpaLasso, DEFAULT_ARTIFACT_DIR};

fn engine() -> Option<Engine> {
    if !artifacts_available(DEFAULT_ARTIFACT_DIR) {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::cpu(DEFAULT_ARTIFACT_DIR).expect("engine"))
}

fn planted(m: usize, n: usize, seed: u64) -> Lasso {
    let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(seed).generate();
    let v = inst.v_star;
    Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v)
}

#[test]
fn objective_artifact_matches_native() {
    let Some(mut engine) = engine() else { return };
    let p = planted(100, 400, 201);
    let mut rng = flexa::prng::Xoshiro256pp::seed_from_u64(5);
    let mut x = vec![0.0; 400];
    rng.fill_normal(&mut x);

    // Row-major A upload.
    let (m, n) = (100, 400);
    let mut a_host = vec![0.0; m * n];
    for j in 0..n {
        let col = p.matrix().col(j);
        for i in 0..m {
            a_host[i * n + j] = col[i];
        }
    }
    let a_buf = engine.buffer_f32(&a_host, &[m, n]).unwrap();
    let b_buf = engine.buffer_f32(p.rhs(), &[m]).unwrap();
    let x_buf = engine.buffer_f32(&x, &[n]).unwrap();
    let c_buf = engine.scalar_f32(p.c()).unwrap();
    let outs = engine
        .run("objective.100x400", &[&a_buf, &b_buf, &x_buf, &c_buf])
        .expect("objective run");
    assert_eq!(outs.len(), 1);
    let v_xla = Engine::to_f64_vec(&outs[0]).unwrap()[0];
    let v_native = p.objective(&x);
    let rel = (v_xla - v_native).abs() / v_native.abs().max(1.0);
    assert!(rel < 1e-4, "objective mismatch: xla {v_xla} vs native {v_native}");
}

#[test]
fn xla_step_matches_native_step() {
    // One FPA iteration via the artifact vs the same math in f64.
    let Some(mut engine) = engine() else { return };
    let p = planted(100, 400, 202);
    let n = 400;
    let mut rng = flexa::prng::Xoshiro256pp::seed_from_u64(6);
    let mut x = vec![0.0; n];
    rng.fill_normal(&mut x);
    for v in x.iter_mut() {
        *v *= 0.1;
    }
    let (tau, gamma, rho) = (5.0, 0.9, 0.5);

    // Native reference step.
    let mut g = vec![0.0; n];
    let f_val = p.grad_and_smooth(&x, &mut g);
    let mut d = vec![0.0; n];
    p.curvature(&x, &mut d);
    let mut xhat = vec![0.0; n];
    let mut e = vec![0.0; n];
    for j in 0..n {
        let denom = d[j] + tau;
        xhat[j] = ops::soft_threshold(x[j] - g[j] / denom, p.c() / denom);
        e[j] = (xhat[j] - x[j]).abs();
    }
    let max_e = e.iter().cloned().fold(0.0, f64::max);
    let mut x_next = x.clone();
    for j in 0..n {
        if e[j] >= rho * max_e {
            x_next[j] = x[j] + gamma * (xhat[j] - x[j]);
        }
    }
    let v_native = f_val + p.reg(&x);

    // XLA step.
    let (m, n_cols) = (100, 400);
    let mut a_host = vec![0.0; m * n_cols];
    for j in 0..n_cols {
        let col = p.matrix().col(j);
        for i in 0..m {
            a_host[i * n_cols + j] = col[i];
        }
    }
    let a_buf = engine.buffer_f32(&a_host, &[m, n_cols]).unwrap();
    let b_buf = engine.buffer_f32(p.rhs(), &[m]).unwrap();
    let x_buf = engine.buffer_f32(&x, &[n_cols]).unwrap();
    let d_buf = engine.buffer_f32(&d, &[n_cols]).unwrap();
    let tau_b = engine.scalar_f32(tau).unwrap();
    let gam_b = engine.scalar_f32(gamma).unwrap();
    let rho_b = engine.scalar_f32(rho).unwrap();
    let c_b = engine.scalar_f32(p.c()).unwrap();
    let outs = engine
        .run(
            "fpa_lasso_step.100x400",
            &[&a_buf, &b_buf, &x_buf, &d_buf, &tau_b, &gam_b, &rho_b, &c_b],
        )
        .expect("fpa step run");
    assert_eq!(outs.len(), 3);
    let x_xla = Engine::to_f64_vec(&outs[0]).unwrap();
    let v_xla = Engine::to_f64_vec(&outs[1]).unwrap()[0];
    let m_xla = Engine::to_f64_vec(&outs[2]).unwrap()[0];

    assert!((v_xla - v_native).abs() / v_native < 1e-4, "{v_xla} vs {v_native}");
    assert!((m_xla - max_e).abs() / max_e.max(1e-9) < 1e-3, "{m_xla} vs {max_e}");
    let mut worst = 0.0f64;
    for j in 0..n_cols {
        worst = worst.max((x_xla[j] - x_next[j]).abs());
    }
    assert!(worst < 1e-4, "x_next mismatch: max abs diff {worst}");
}

#[test]
fn xla_solver_converges_like_native() {
    let Some(mut engine) = engine() else { return };
    let p = planted(200, 1000, 203);
    let opts = SolveOptions::default().with_max_iters(800).with_target(5e-5);

    let native = Fpa::paper_defaults(&p).solve(&p, &opts);
    let mut xla = XlaFpaLasso::new(&mut engine, 200, 1000).expect("artifact");
    let xla_report = xla.solve(&p, &opts).expect("xla solve");

    // f32 artifacts bottom out around 1e-6 relative; both must reach the
    // 5e-5 target or get close.
    assert!(
        native.trace.best_rel_err() < 1e-4,
        "native best {:.3e}",
        native.trace.best_rel_err()
    );
    assert!(
        xla_report.trace.best_rel_err() < 1e-3,
        "xla best {:.3e}",
        xla_report.trace.best_rel_err()
    );
}

#[test]
fn fista_artifact_runs() {
    let Some(mut engine) = engine() else { return };
    let p = planted(100, 400, 204);
    let (m, n) = (100, 400);
    let mut a_host = vec![0.0; m * n];
    for j in 0..n {
        let col = p.matrix().col(j);
        for i in 0..m {
            a_host[i * n + j] = col[i];
        }
    }
    let l = p.lipschitz_grad();
    let a_buf = engine.buffer_f32(&a_host, &[m, n]).unwrap();
    let b_buf = engine.buffer_f32(p.rhs(), &[m]).unwrap();
    let mut y = vec![0.0; n];
    let mut x_prev = vec![0.0; n];
    let mut t = 1.0f64;
    let mut v_first = None;
    for _ in 0..50 {
        let y_buf = engine.buffer_f32(&y, &[n]).unwrap();
        let xp_buf = engine.buffer_f32(&x_prev, &[n]).unwrap();
        let t_buf = engine.scalar_f32(t).unwrap();
        let il_buf = engine.scalar_f32(1.0 / l).unwrap();
        let c_buf = engine.scalar_f32(p.c()).unwrap();
        let outs = engine
            .run("fista_step.100x400", &[&a_buf, &b_buf, &y_buf, &xp_buf, &t_buf, &il_buf, &c_buf])
            .expect("fista step");
        let x_next = Engine::to_f64_vec(&outs[0]).unwrap();
        let y_next = Engine::to_f64_vec(&outs[1]).unwrap();
        t = Engine::to_f64_vec(&outs[2]).unwrap()[0];
        x_prev = x_next;
        y = y_next;
        if v_first.is_none() {
            v_first = Some(p.objective(&x_prev));
        }
    }
    let v_final = p.objective(&x_prev);
    assert!(v_final < v_first.unwrap(), "FISTA via artifact must descend");
}
