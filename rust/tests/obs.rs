//! Loopback integration tests for `flexa::obs`: `/metrics` stays valid
//! Prometheus text while jobs churn and concurrent scrapes race the
//! workers, the per-job profile's phases account for the job's total
//! time, `/v1/debug/trace` serves parseable Chrome trace-event JSON
//! carrying the request id, and the uptime gauge is monotone.

use flexa::http::{HttpConfig, HttpServer, SpawnedServer};
use flexa::serve::{Json, ServeConfig};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn spawn(http: HttpConfig, serve: ServeConfig) -> SpawnedServer {
    HttpServer::bind("127.0.0.1:0", http, serve, flexa::api::Registry::with_defaults())
        .expect("bind loopback server")
        .spawn()
}

/// One `Connection: close` exchange; returns (status, body).
fn req(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).unwrap();
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).unwrap();
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let raw = String::from_utf8(raw).expect("utf8 response");
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw.as_str(), ""));
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response head: {head}"));
    (status, body.to_string())
}

fn post_job(addr: &str, spec: &str) -> u64 {
    let (status, body) = req(addr, "POST", "/v1/jobs", Some(spec));
    assert_eq!(status, 202, "POST /v1/jobs: {body}");
    let doc = Json::parse(&body).expect("valid submit response");
    doc.get("job").and_then(Json::as_f64).expect("job id") as u64
}

fn wait_finished(addr: &str, job: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, body) = req(addr, "GET", &format!("/v1/jobs/{job}"), None);
        assert_eq!(status, 200, "GET /v1/jobs/{job}: {body}");
        let doc = Json::parse(&body).expect("valid status json");
        if doc.get("state").and_then(Json::as_str) == Some("finished") {
            return doc;
        }
        assert!(Instant::now() < deadline, "job {job} never finished: {body}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn job_spec(i: usize) -> String {
    format!(
        "{{\"problem\":\"lasso\",\"rows\":25,\"cols\":75,\"seed\":7,\"algo\":\"fpa\",\
         \"max_iters\":40,\"target\":0,\"tag\":\"obs-{i}\"}}"
    )
}

/// Minimal Prometheus text-format validator: every sample line belongs
/// to a `# TYPE`-declared family, histogram bucket series are strictly
/// `le`-ordered and cumulative, and each series' `+Inf` bucket equals
/// its `_count`.
fn validate_prometheus(text: &str) {
    struct Hist {
        last_le: f64,
        last_cum: f64,
        inf: Option<f64>,
    }
    let mut types: HashMap<String, String> = HashMap::new();
    let mut hists: HashMap<String, Hist> = HashMap::new();
    let mut counts: HashMap<String, f64> = HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line has a name").to_string();
            let kind = it.next().expect("TYPE line has a kind").to_string();
            types.insert(name, kind);
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let (key, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("malformed sample: {line}"));
        let value: f64 =
            value.parse().unwrap_or_else(|_| panic!("non-numeric sample value: {line}"));
        let name_end = key.find('{').unwrap_or(key.len());
        let name = &key[..name_end];
        let labels = &key[name_end..];
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|b| types.get(*b).map(String::as_str) == Some("histogram"))
            .unwrap_or(name);
        assert!(types.contains_key(base), "sample `{name}` has no # TYPE line: {line}");
        if name.ends_with("_bucket") && types.get(base).map(String::as_str) == Some("histogram")
        {
            let inner = labels.trim_start_matches('{').trim_end_matches('}');
            let mut le = None;
            let mut rest: Vec<&str> = Vec::new();
            for part in inner.split(',').filter(|p| !p.is_empty()) {
                match part.strip_prefix("le=\"") {
                    Some(v) => le = Some(v.trim_end_matches('"').to_string()),
                    None => rest.push(part),
                }
            }
            let le = le.unwrap_or_else(|| panic!("bucket sample without le: {line}"));
            let le_val = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse::<f64>().unwrap_or_else(|_| panic!("bad le `{le}`: {line}"))
            };
            let series = if rest.is_empty() {
                base.to_string()
            } else {
                format!("{base}{{{}}}", rest.join(","))
            };
            let h = hists
                .entry(series.clone())
                .or_insert(Hist { last_le: f64::NEG_INFINITY, last_cum: 0.0, inf: None });
            assert!(le_val > h.last_le, "le out of order in `{series}`: {line}");
            assert!(
                value >= h.last_cum,
                "buckets must be cumulative in `{series}`: {line} (prev {})",
                h.last_cum
            );
            h.last_le = le_val;
            h.last_cum = value;
            if le_val.is_infinite() {
                h.inf = Some(value);
            }
        } else if let Some(b) = name.strip_suffix("_count") {
            if types.get(b).map(String::as_str) == Some("histogram") {
                let series = if labels.is_empty() {
                    b.to_string()
                } else {
                    format!("{b}{labels}")
                };
                counts.insert(series, value);
            }
        }
    }
    for (series, h) in &hists {
        let inf = h.inf.unwrap_or_else(|| panic!("series `{series}` has no +Inf bucket"));
        let count = counts
            .get(series)
            .unwrap_or_else(|| panic!("series `{series}` has buckets but no _count"));
        assert_eq!(inf, *count, "`{series}`: +Inf bucket must equal _count");
    }
}

/// Extract one unlabeled gauge/counter value from a scrape.
fn sample(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .unwrap_or_else(|| panic!("no `{name}` sample in:\n{text}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("bad `{name}` value: {e}"))
}

/// Tentpole acceptance: the four obs histogram families land in
/// `/metrics`, populated by real traffic, and every concurrent scrape
/// taken *while* jobs churn parses as valid Prometheus text.
#[test]
fn metrics_histograms_stay_valid_prometheus_under_churn() {
    let server = spawn(HttpConfig::default(), ServeConfig::default().with_workers(2));
    let addr = server.addr().to_string();

    let scraper = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            for _ in 0..12 {
                let (status, body) = req(&addr, "GET", "/metrics", None);
                assert_eq!(status, 200);
                validate_prometheus(&body);
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };
    let jobs: Vec<u64> = (0..6).map(|i| post_job(&addr, &job_spec(i))).collect();
    for job in &jobs {
        wait_finished(&addr, *job);
    }
    scraper.join().expect("scraper thread");

    let (status, body) = req(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    validate_prometheus(&body);
    for family in [
        "flexa_http_request_duration_seconds",
        "flexa_job_queue_seconds",
        "flexa_job_service_seconds",
        "flexa_job_iteration_seconds",
    ] {
        assert!(
            body.contains(&format!("# TYPE {family} histogram")),
            "missing histogram family `{family}`:\n{body}"
        );
    }
    // Traffic populated them: 6 jobs served, 6 × 40 iterations timed,
    // and the POSTs themselves recorded under their endpoint label.
    assert!(sample(&body, "flexa_job_service_seconds_count") >= 6.0, "{body}");
    assert!(sample(&body, "flexa_job_queue_seconds_count") >= 6.0, "{body}");
    let iter_count: f64 = body
        .lines()
        .filter(|l| l.starts_with("flexa_job_iteration_seconds_count"))
        .filter_map(|l| l.rsplit_once(' ').and_then(|(_, v)| v.parse::<f64>().ok()))
        .sum();
    assert!(iter_count >= 240.0, "iteration histogram undercounts: {iter_count}\n{body}");
    assert!(
        body.contains("flexa_http_request_duration_seconds_count{endpoint=\"post_jobs\"}"),
        "{body}"
    );
    assert!(body.contains("flexa_obs_spans_dropped_total "), "{body}");
    server.shutdown().expect("clean shutdown");
}

/// The per-job profile accounts for the job's life: queue + service
/// bound the total, the kernel region fits inside service time, and the
/// iteration count matches the solve.
#[test]
fn job_profile_phases_account_for_total_time() {
    let server = spawn(HttpConfig::default(), ServeConfig::default().with_workers(1));
    let addr = server.addr().to_string();
    let job = post_job(&addr, &job_spec(0));
    wait_finished(&addr, job);

    let (status, body) = req(&addr, "GET", &format!("/v1/jobs/{job}/profile"), None);
    assert_eq!(status, 200, "{body}");
    let p = Json::parse(&body).expect("profile JSON must parse");
    assert_eq!(p.get("job").and_then(Json::as_f64), Some(job as f64));
    assert_eq!(p.get("state").and_then(Json::as_str), Some("done"), "{body}");
    assert_eq!(p.get("solver").and_then(Json::as_str), Some("fpa"), "{body}");
    let num = |k: &str| p.get(k).and_then(Json::as_f64).unwrap_or_else(|| panic!("{k}: {body}"));
    let (queue_ms, service_ms, kernel_ms, total_ms) =
        (num("queue_ms"), num("service_ms"), num("kernel_ms"), num("total_ms"));
    assert!(queue_ms >= 0.0 && service_ms > 0.0 && total_ms > 0.0, "{body}");
    // No retries here, so enqueue→terminal is queue-wait plus one
    // service stint (plus scheduler bookkeeping, hence the slack).
    assert!(
        queue_ms + service_ms <= total_ms + 5.0,
        "phases exceed total: queue {queue_ms} + service {service_ms} > total {total_ms}"
    );
    assert!(kernel_ms <= service_ms + 1.0, "kernel {kernel_ms} outside service {service_ms}");
    let iters = p.get("iterations").expect("iterations object");
    assert_eq!(iters.get("count").and_then(Json::as_f64), Some(40.0), "{body}");
    assert!(
        iters.get("total_ms").and_then(Json::as_f64).unwrap_or(-1.0) <= service_ms + 1.0,
        "{body}"
    );

    // Unknown job → 404; wrong method → 405.
    let (status, _) = req(&addr, "GET", "/v1/jobs/99999/profile", None);
    assert_eq!(status, 404);
    let (status, _) = req(&addr, "POST", "/v1/jobs/1/profile", Some("{}"));
    assert_eq!(status, 405);
    server.shutdown().expect("clean shutdown");
}

/// `/v1/debug/trace` round-trips through the JSON parser, carries the
/// expected phases with job attribution, respects `since_ms`, and
/// rejects non-GET methods.
#[test]
fn debug_trace_serves_parseable_trace_events() {
    let server = spawn(HttpConfig::default(), ServeConfig::default().with_workers(1));
    let addr = server.addr().to_string();
    let job = post_job(&addr, &job_spec(0));
    wait_finished(&addr, job);

    let (status, body) = req(&addr, "GET", "/v1/debug/trace", None);
    assert_eq!(status, 200, "{body}");
    let doc = Json::parse(&body).expect("trace JSON must parse");
    let Some(Json::Arr(events)) = doc.get("traceEvents") else {
        panic!("traceEvents must be an array: {body}");
    };
    assert!(!events.is_empty(), "trace must carry spans after a solve");
    let mut phases: Vec<&str> = Vec::new();
    let mut saw_job = false;
    let mut saw_request = false;
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
        let name = e.get("name").and_then(Json::as_str).expect("event name");
        phases.push(name);
        if let Some(args) = e.get("args") {
            saw_job |= args.get("job").and_then(Json::as_f64) == Some(job as f64);
            saw_request |= args.get("request").and_then(Json::as_str).is_some();
        }
    }
    for phase in ["queue.wait", "solve.iter"] {
        assert!(phases.contains(&phase), "missing `{phase}` span in {phases:?}");
    }
    assert!(saw_job, "no span attributed to job {job}: {body}");
    assert!(saw_request, "no span carries a request id: {body}");

    // A since_ms cursor far in the future filters everything out but
    // still renders a valid (empty) document.
    let (status, body) = req(&addr, "GET", "/v1/debug/trace?since_ms=9999999999", None);
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("empty trace parses");
    let Some(Json::Arr(events)) = doc.get("traceEvents") else { panic!("{body}") };
    assert!(events.is_empty(), "future cursor must filter all spans: {body}");

    let (status, _) = req(&addr, "DELETE", "/v1/debug/trace", None);
    assert_eq!(status, 405);
    server.shutdown().expect("clean shutdown");
}

/// `flexa_uptime_seconds` regression: monotone across scrapes and
/// immune to wall-clock semantics (it derives from a bind-time
/// `Instant`, so it can never go negative or jump backwards).
#[test]
fn uptime_gauge_is_monotone_across_scrapes() {
    let server = spawn(HttpConfig::default(), ServeConfig::default().with_workers(1));
    let addr = server.addr().to_string();
    let (_, first) = req(&addr, "GET", "/metrics", None);
    let up1 = sample(&first, "flexa_uptime_seconds");
    std::thread::sleep(Duration::from_millis(30));
    let (_, second) = req(&addr, "GET", "/metrics", None);
    let up2 = sample(&second, "flexa_uptime_seconds");
    assert!(up1 >= 0.0, "uptime can never be negative: {up1}");
    assert!(up2 >= up1, "uptime must be monotone: {up1} then {up2}");
    server.shutdown().expect("clean shutdown");
}

/// `--quiet-probes` policy: successful probe endpoints are suppressed,
/// everything else — and every failure — still logs.
#[test]
fn quiet_probes_suppresses_only_successful_probe_lines() {
    use flexa::http::should_log;
    // Default: everything logs.
    assert!(should_log(false, "/healthz", 200));
    assert!(should_log(false, "/metrics", 200));
    // Quiet: probe endpoints suppressed on success only.
    assert!(!should_log(true, "/healthz", 200));
    assert!(!should_log(true, "/metrics", 200));
    assert!(should_log(true, "/healthz", 503), "failures always log");
    assert!(should_log(true, "/metrics", 401), "failures always log");
    // Quiet never touches real traffic.
    assert!(should_log(true, "/v1/jobs", 202));
    assert!(should_log(true, "/v1/jobs/1/profile", 200));
}
