//! Integration tests for the `flexa::tenant` control plane wired
//! through the scheduler: weighted-fair dispatch (1:3 completes ≈1:3,
//! deterministically), admission and dispatch quotas, the bounded-
//! backoff retry policy, and the persistent warm-start store surviving
//! a scheduler "restart" (new scheduler, same store file) — including
//! corrupt-store robustness.

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Registry, SolverSpec};
use flexa::serve::{
    CollectServeObserver, JobEvent, JobOutcome, JobSpec, RetryPolicy, Scheduler, ServeConfig,
};
use flexa::tenant::{Tenant, TenantQuota, TenantRegistry};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_job(seed: u64) -> JobSpec {
    JobSpec::new(ProblemSpec::lasso(15, 45).with_seed(seed), SolverSpec::parse("fpa").unwrap())
        .with_opts(SolveOptions::default().with_max_iters(8).with_target(0.0))
}

fn long_job() -> JobSpec {
    JobSpec::new(
        ProblemSpec::lasso(40, 120).with_sparsity(0.1).with_seed(901),
        SolverSpec::parse("fpa").unwrap(),
    )
    .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0))
}

fn wait_until(f: impl Fn() -> bool, timeout: Duration) -> bool {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    f()
}

/// The acceptance scenario: tenants with weights 1:3 under sustained
/// contention complete work in exactly the DRR interleave a,b,b,b,…
/// (deterministic with one worker and a pre-filled queue), hence ≈1:3
/// in every window — and neither starves.
#[test]
fn weighted_fairness_one_to_three_under_contention() {
    let tenants = TenantRegistry::new(vec![
        Tenant::new("a").with_weight(1),
        Tenant::new("b").with_weight(3),
    ])
    .unwrap();
    let obs = CollectServeObserver::new();
    let s = Scheduler::start_with(
        ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    // Stall the single worker so both tenants' queues fill while it is
    // busy; the blocker runs under `default` and is cancelled once the
    // backlog is in place.
    let blocker = s.submit(long_job());
    assert!(
        wait_until(
            || obs.job_events(blocker.id()).iter().any(|e| matches!(e, JobEvent::Started { .. })),
            Duration::from_secs(30),
        ),
        "blocker never started"
    );
    let mut ids_by_tenant: Vec<(u64, &str)> = Vec::new();
    for i in 0..4 {
        ids_by_tenant.push((s.submit(tiny_job(10 + i).with_tenant("a")).id(), "a"));
    }
    for i in 0..12 {
        ids_by_tenant.push((s.submit(tiny_job(50 + i).with_tenant("b")).id(), "b"));
    }
    blocker.cancel();
    let results = s.join();
    assert_eq!(results.len(), 17);
    assert!(results.iter().all(|r| !matches!(r.outcome, JobOutcome::Failed { .. })));

    // Reconstruct the dispatch order from Started events (single worker
    // ⇒ strictly sequential), drop the blocker, map ids to tenants.
    let tenant_of = |id: u64| -> &str {
        ids_by_tenant.iter().find(|(j, _)| *j == id).map(|(_, t)| *t).unwrap_or("blocker")
    };
    let order: Vec<&str> = obs
        .events()
        .iter()
        .filter_map(|e| match e {
            JobEvent::Started { job, .. } => Some(tenant_of(*job)),
            _ => None,
        })
        .filter(|t| *t != "blocker")
        .collect();
    let expected: Vec<&str> =
        vec!["a", "b", "b", "b", "a", "b", "b", "b", "a", "b", "b", "b", "a", "b", "b", "b"];
    assert_eq!(order, expected, "DRR dispatch order is the deterministic 1:3 interleave");
    // Proportion check (the ≈1:3 acceptance bound) over every 4-window.
    for (w, window) in order.chunks(4).enumerate() {
        let b_share = window.iter().filter(|t| **t == "b").count();
        assert_eq!(b_share, 3, "window {w}: weight-3 tenant gets 3 of every 4 slots");
    }
    // Starvation-freedom: tenant a appears in every round.
    assert!(order.iter().take(4).any(|t| *t == "a"), "light tenant served in round one");

    // Per-tenant counters add up.
    // (tenant_stats needs a live scheduler; recompute from results.)
    let a_done = results.iter().filter(|r| r.tenant == "a").count();
    let b_done = results.iter().filter(|r| r.tenant == "b").count();
    assert_eq!((a_done, b_done), (4, 12));
}

/// `max_concurrent` gates dispatch, not admission: the capped tenant's
/// second job waits while another tenant's job runs on the free worker.
#[test]
fn max_concurrent_caps_dispatch_without_bouncing_jobs() {
    let tenants = TenantRegistry::new(vec![Tenant::new("capped")
        .with_quota(TenantQuota::unlimited().with_max_concurrent(1))])
    .unwrap();
    let obs = CollectServeObserver::new();
    let s = Scheduler::start_with(
        ServeConfig::default().with_workers(2).with_cache_bytes(0).with_tenants(tenants),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    // Two long jobs for the capped tenant, then a tiny default job.
    // Submission order guarantees capped#1 is popped first; capped#2 is
    // then blocked by the concurrency gate, so worker 2 must take the
    // tiny job even though it was submitted last.
    let c1 = s.submit(long_job().with_tenant("capped").with_tag("c1"));
    let c2 = s.submit(long_job().with_tenant("capped").with_tag("c2"));
    let tiny = s.submit(tiny_job(3).with_tag("tiny"));
    assert!(
        wait_until(
            || obs.job_events(tiny.id()).iter().any(|e| matches!(e, JobEvent::Finished { .. })),
            Duration::from_secs(60),
        ),
        "tiny job never finished — the capped tenant hogged both workers"
    );
    // While the tiny job ran to completion, capped#2 never started.
    assert!(
        !obs.job_events(c2.id()).iter().any(|e| matches!(e, JobEvent::Started { .. })),
        "second capped job must wait for the first to finish"
    );
    c1.cancel();
    // Once capped#1 finishes, capped#2 dispatches (then is cancelled).
    assert!(
        wait_until(
            || obs.job_events(c2.id()).iter().any(|e| matches!(e, JobEvent::Started { .. })),
            Duration::from_secs(60),
        ),
        "second capped job never dispatched after the slot freed"
    );
    c2.cancel();
    let results = s.join();
    assert_eq!(results.len(), 3, "admission never bounced anything");
}

/// Retry policy: a transiently-failing custom build succeeds on the
/// third attempt; retry counters/events line up; registry resolution
/// errors stay final; exhausted retries end in Failed.
#[test]
fn retry_policy_reruns_transient_failures_with_backoff() {
    let obs = CollectServeObserver::new();
    let s = Scheduler::start_with(
        ServeConfig::default()
            .with_workers(1)
            .with_cache_bytes(0)
            .with_retry_policy(RetryPolicy { max_retries: 3, base_backoff_ms: 1, max_backoff_ms: 8 }),
        Some(obs.clone()),
        Registry::with_defaults(),
    );

    // Fails twice, then builds fine.
    let attempts = Arc::new(AtomicUsize::new(0));
    let a = Arc::clone(&attempts);
    let build: flexa::serve::CustomProblemFn = Arc::new(move || {
        let n = a.fetch_add(1, Ordering::SeqCst);
        if n < 2 {
            anyhow::bail!("transient backend hiccup #{n}");
        }
        let inst = flexa::datagen::NesterovLasso::new(12, 36, 0.1, 1.0).seed(6).generate();
        Ok(flexa::api::ProblemHandle::least_squares(flexa::problems::lasso::Lasso::new(
            inst.a, inst.b, 0.5,
        )))
    });
    let flaky = s.submit(
        JobSpec::custom("flaky", build, SolverSpec::parse("fpa").unwrap())
            .with_opts(SolveOptions::default().with_max_iters(5).with_target(0.0)),
    );

    // Deterministic misconfiguration: never retried despite the policy.
    let misconfigured =
        s.submit(JobSpec::new(ProblemSpec::lasso(10, 30), SolverSpec::new("no-such-solver")));

    // Always fails: retries exhaust, terminal outcome is Failed.
    let hopeless_build: flexa::serve::CustomProblemFn =
        Arc::new(|| anyhow::bail!("permanently broken"));
    let hopeless = s.submit(JobSpec::custom(
        "hopeless",
        hopeless_build,
        SolverSpec::parse("fpa").unwrap(),
    ));

    let results = s.join();
    assert_eq!(results.len(), 3);

    let flaky_result = results.iter().find(|r| r.job == flaky.id()).unwrap();
    assert!(flaky_result.outcome.is_done(), "{:?}", flaky_result.outcome);
    assert_eq!(attempts.load(Ordering::SeqCst), 3, "two failures + one success");
    let flaky_events = obs.job_events(flaky.id());
    let retries: Vec<(u32, u64)> = flaky_events
        .iter()
        .filter_map(|e| match e {
            JobEvent::Retrying { attempt, delay_ms, .. } => Some((*attempt, *delay_ms)),
            _ => None,
        })
        .collect();
    assert_eq!(retries, vec![(1, 1), (2, 2)], "exponential backoff per attempt");
    let starts =
        flaky_events.iter().filter(|e| matches!(e, JobEvent::Started { .. })).count();
    assert_eq!(starts, 3, "one Started per attempt");
    assert!(matches!(flaky_events.last(), Some(JobEvent::Finished { .. })));

    let mis = results.iter().find(|r| r.job == misconfigured.id()).unwrap();
    assert!(matches!(mis.outcome, JobOutcome::Failed { .. }));
    assert!(
        !obs.job_events(misconfigured.id())
            .iter()
            .any(|e| matches!(e, JobEvent::Retrying { .. })),
        "registry resolution errors are not retryable"
    );

    let hp = results.iter().find(|r| r.job == hopeless.id()).unwrap();
    match &hp.outcome {
        JobOutcome::Failed { error } => assert!(error.contains("permanently"), "{error}"),
        other => panic!("expected Failed after exhausted retries, got {other:?}"),
    }
    let hp_retries = obs
        .job_events(hopeless.id())
        .iter()
        .filter(|e| matches!(e, JobEvent::Retrying { .. }))
        .count();
    assert_eq!(hp_retries, 3, "exactly max_retries attempts were scheduled");
}

/// Retry counters surface in `stats()`, `tenant_stats()` and the
/// status table.
#[test]
fn retry_counters_surface_in_stats_and_status() {
    let s = Scheduler::start(
        ServeConfig::default()
            .with_workers(1)
            .with_cache_bytes(0)
            .with_retry_policy(RetryPolicy { max_retries: 2, base_backoff_ms: 1, max_backoff_ms: 4 }),
    );
    let build: flexa::serve::CustomProblemFn = Arc::new(|| anyhow::bail!("nope"));
    let h = s.submit(JobSpec::custom("failing", build, SolverSpec::parse("fpa").unwrap()));
    assert!(
        wait_until(|| s.stats().finished() == 1, Duration::from_secs(60)),
        "job never reached a terminal state"
    );
    assert_eq!(s.stats().retried, 2);
    let ts = s.tenant_stats();
    let def = ts.iter().find(|t| t.tenant == "default").unwrap();
    assert_eq!(def.retried, 2);
    assert_eq!(def.finished, 1);
    let st = s.status(h.id()).unwrap();
    assert_eq!(st.retries, 2, "status carries the retry count");
    s.join();
}

/// The persistence acceptance scenario: scheduler #1 fills the store
/// through warm-started solves; scheduler #2 (same store file — a
/// simulated process restart) reloads it and its *first* solve hits the
/// cache, reuses the Lipschitz estimate, and needs fewer iterations.
#[test]
fn restarted_scheduler_reloads_the_warm_start_store() {
    let store = std::env::temp_dir()
        .join(format!("flexa_tenant_restart_{}.bin", std::process::id()));
    std::fs::remove_file(&store).ok();
    let spec = ProblemSpec::lasso(40, 120).with_sparsity(0.1).with_seed(654);
    let opts = SolveOptions::default().with_max_iters(50_000).with_target(1e-3);
    let job = || {
        JobSpec::new(spec.clone(), SolverSpec::parse("fista").unwrap())
            .with_opts(opts.clone())
            .with_warm_start(true)
    };

    // First "process": cold solve, store written.
    let s1 = Scheduler::start(
        ServeConfig::default().with_workers(1).with_store_path(&store),
    );
    s1.submit(job());
    let (results1, stats1) = s1.join_with_stats();
    assert!(results1[0].outcome.is_done());
    let cold_iters = results1[0].report.as_ref().unwrap().iterations;
    assert_eq!(stats1.hits, 0, "first process starts cold");

    // Second "process": fresh scheduler, same store file.
    let s2 = Scheduler::start(
        ServeConfig::default().with_workers(1).with_store_path(&store),
    );
    let loaded = s2.store_stats().expect("store configured");
    assert!(loaded.entries_loaded >= 1, "restart replayed the store: {loaded:?}");
    assert_eq!(loaded.records_skipped, 0);
    s2.submit(job());
    let (results2, stats2) = s2.join_with_stats();
    assert!(results2[0].outcome.is_done());
    assert_eq!(stats2.hits, 1, "the restarted process's first solve hits: {stats2:?}");
    assert!(
        stats2.lipschitz_reuses >= 1,
        "the stored Lipschitz estimate must be reused: {stats2:?}"
    );
    assert!(
        matches!(results2[0].outcome, JobOutcome::Done { warm_started: true, .. }),
        "{:?}",
        results2[0].outcome
    );
    let warm_iters = results2[0].report.as_ref().unwrap().iterations;
    assert!(
        warm_iters < cold_iters,
        "warm restart {warm_iters} vs cold {cold_iters} iterations — the stored x⁰ must reduce work"
    );
    std::fs::remove_file(&store).ok();
}

/// Corrupt / truncated / non-store files are detected by checksum and
/// skipped — the scheduler still starts, serves jobs and repairs the
/// file for the next run.
#[test]
fn corrupt_store_files_are_skipped_not_crashed_on() {
    let store = std::env::temp_dir()
        .join(format!("flexa_tenant_corrupt_{}.bin", std::process::id()));
    std::fs::write(&store, b"garbage garbage garbage garbage garbage").unwrap();
    let s = Scheduler::start(
        ServeConfig::default().with_workers(1).with_store_path(&store),
    );
    let st = s.store_stats().expect("store configured despite corruption");
    assert_eq!(st.entries_loaded, 0);
    assert!(st.records_skipped >= 1, "{st:?}");
    // Still fully operational: a warm-start pair behaves normally and
    // repopulates the (now-repaired) store.
    let spec = ProblemSpec::lasso(30, 90).with_sparsity(0.1).with_seed(77);
    let opts = SolveOptions::default().with_max_iters(20_000).with_target(1e-4);
    for _ in 0..2 {
        s.submit(
            JobSpec::new(spec.clone(), SolverSpec::parse("fpa").unwrap())
                .with_opts(opts.clone())
                .with_warm_start(true),
        );
    }
    let (results, stats) = s.join_with_stats();
    assert!(results.iter().all(|r| r.outcome.is_done()));
    assert_eq!((stats.hits, stats.misses), (1, 1));

    // Third run over the repaired file: clean load.
    let s = Scheduler::start(
        ServeConfig::default().with_workers(1).with_store_path(&store),
    );
    let st = s.store_stats().unwrap();
    assert_eq!(st.records_skipped, 0, "{st:?}");
    assert!(st.entries_loaded >= 1);
    s.join();
    std::fs::remove_file(&store).ok();
}

/// Single-tenant submissions through the tenant-aware queue stay FIFO:
/// dispatch order equals submission order (the golden-stream guarantee
/// the DRR queue must preserve).
#[test]
fn default_tenant_dispatch_is_fifo() {
    let obs = CollectServeObserver::new();
    let s = Scheduler::start_with(
        ServeConfig::default().with_workers(1).with_cache_bytes(0),
        Some(obs.clone()),
        Registry::with_defaults(),
    );
    let ids: Vec<u64> = (0..6).map(|i| s.submit(tiny_job(i)).id()).collect();
    s.join();
    let started: Vec<u64> = obs
        .events()
        .iter()
        .filter_map(|e| match e {
            JobEvent::Started { job, .. } => Some(*job),
            _ => None,
        })
        .collect();
    assert_eq!(started, ids, "single-tenant order is submission order");
}
