//! Loopback integration tests for `flexa::http`: a λ-sweep POSTed over
//! HTTP is bit-identical to direct `Session` runs and warm-starts
//! through the cache (visible in `/metrics`), the SSE stream delivers
//! the full lifecycle, a full queue returns 429 without deadlocking,
//! DELETE mid-run cancels, and the jobfile error paths surface as
//! actionable 400/413 responses.

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Registry, Session, SolverSpec};
use flexa::http::{HttpConfig, HttpServer, SpawnedServer};
use flexa::serve::{Json, ServeConfig};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn spawn(http: HttpConfig, serve: ServeConfig) -> SpawnedServer {
    HttpServer::bind("127.0.0.1:0", http, serve, Registry::with_defaults())
        .expect("bind loopback server")
        .spawn()
}

/// One `Connection: close` exchange; returns (status, headers, body).
fn req(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, Vec<(String, String)>, String) {
    req_with(addr, method, path, body, &[])
}

/// [`req`] with extra request headers (e.g. `Authorization`).
fn req_with(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    extra_headers: &[(&str, &str)],
) -> (u16, Vec<(String, String)>, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    for (name, value) in extra_headers {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).unwrap();
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).unwrap();
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let raw = String::from_utf8(raw).expect("utf8 response");
    parse_response(&raw)
}

fn parse_response(raw: &str) -> (u16, Vec<(String, String)>, String) {
    let (head, body) = raw.split_once("\r\n\r\n").unwrap_or((raw, ""));
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response head: {head}"));
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    (status, headers, body.to_string())
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// POST one job spec, asserting 202; returns the job id.
fn post_job(addr: &str, spec: &str) -> u64 {
    let (status, _, body) = req(addr, "POST", "/v1/jobs", Some(spec));
    assert_eq!(status, 202, "POST /v1/jobs: {body}");
    let doc = Json::parse(&body).expect("valid submit response");
    doc.get("job").and_then(|v| v.as_f64()).expect("job id") as u64
}

/// Poll `GET /v1/jobs/{id}?x=1` until the job finishes; returns the
/// status document.
fn wait_finished(addr: &str, job: u64) -> Json {
    wait_finished_with(addr, job, &[])
}

/// [`wait_finished`] with extra request headers — job visibility is
/// tenant-scoped, so polling another tenant's job needs its credential.
fn wait_finished_with(addr: &str, job: u64, extra_headers: &[(&str, &str)]) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, _, body) =
            req_with(addr, "GET", &format!("/v1/jobs/{job}?x=1"), None, extra_headers);
        assert_eq!(status, 200, "GET /v1/jobs/{job}: {body}");
        let doc = Json::parse(&body).expect("valid status json");
        if doc.get("state").and_then(|v| v.as_str()) == Some("finished") {
            return doc;
        }
        assert!(Instant::now() < deadline, "job {job} never finished: {body}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn poll_until_running(addr: &str, job: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, _, body) = req(addr, "GET", &format!("/v1/jobs/{job}"), None);
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).unwrap();
        match doc.get("state").and_then(|v| v.as_str()) {
            Some("running") => return,
            Some("finished") => panic!("job {job} finished before it could be observed running"),
            _ => {}
        }
        assert!(Instant::now() < deadline, "job {job} never started");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn x_of(doc: &Json) -> Vec<f64> {
    let Some(Json::Arr(items)) = doc.get("x") else { panic!("status has no x array: {doc:?}") };
    items.iter().map(|v| v.as_f64().expect("x entries are numbers")).collect()
}

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn sweep_spec(i: usize, lambda: f64, warm: bool) -> String {
    format!(
        "{{\"problem\":\"lasso\",\"rows\":30,\"cols\":90,\"seed\":11,\"lambda\":{lambda},\
         \"algo\":\"fpa\",\"max_iters\":80,\"warm_start\":{warm},\"tag\":\"sweep-{i}\"}}"
    )
}

/// The acceptance scenario: 8 sequential λ-sweep submissions are
/// bit-identical to direct `Session` runs; the SSE stream carries the
/// full `queued → started → iteration* → finished` lifecycle; re-running
/// the sweep warm-started shows cache hits in `/metrics`.
#[test]
fn lambda_sweep_over_http_matches_session_and_warm_starts() {
    let server = spawn(HttpConfig::default(), ServeConfig::default().with_workers(1));
    let addr = server.addr().to_string();
    let lambdas: Vec<f64> = (0..8).map(|i| 2.0 * 0.7f64.powi(i)).collect();

    // --- cold pass: deterministic, compare against Session bit-for-bit ---
    let mut last_cold_job = 0;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let job = post_job(&addr, &sweep_spec(i, lambda, false));
        let doc = wait_finished(&addr, job);
        assert_eq!(doc.get("outcome").and_then(|v| v.as_str()), Some("done"), "{doc:?}");
        assert_eq!(doc.get("iterations").and_then(|v| v.as_f64()), Some(80.0));
        assert_eq!(doc.get("tag").and_then(|v| v.as_str()), Some(format!("sweep-{i}").as_str()));

        let reference = Session::problem(
            ProblemSpec::lasso(30, 90).with_seed(11).with_lambda(lambda),
        )
        .solver(SolverSpec::parse("fpa").unwrap())
        .options(SolveOptions::default().with_max_iters(80))
        .run()
        .expect("session reference run");
        assert_eq!(reference.report.iterations, 80);
        let http_x = x_of(&doc);
        assert_eq!(
            bits(&http_x),
            bits(&reference.report.x),
            "lambda {lambda}: HTTP result must be bit-identical to Session"
        );
        let objective = doc.get("objective").and_then(|v| v.as_f64()).expect("objective");
        assert_eq!(objective.to_bits(), reference.report.objective.to_bits());
        last_cold_job = job;
    }

    // --- SSE replay of a finished job: the complete lifecycle, in order ---
    let (status, _, sse) =
        req(&addr, "GET", &format!("/v1/jobs/{last_cold_job}/events"), None);
    assert_eq!(status, 200);
    let events: Vec<&str> =
        sse.lines().filter_map(|l| l.strip_prefix("event: ")).collect();
    assert_eq!(events.first(), Some(&"queued"), "{events:?}");
    assert_eq!(events.get(1), Some(&"started"), "{events:?}");
    assert_eq!(events.last(), Some(&"finished"), "{events:?}");
    assert_eq!(events.iter().filter(|e| **e == "iteration").count(), 80);
    assert!(sse.contains("data: {\"event\":\"finished\""), "data frames carry the JSONL encoding");

    // --- warm pass: same sweep with warm_start; hits land in /metrics ---
    for (i, &lambda) in lambdas.iter().enumerate() {
        let job = post_job(&addr, &sweep_spec(i, lambda, true));
        let doc = wait_finished(&addr, job);
        assert_eq!(doc.get("outcome").and_then(|v| v.as_str()), Some("done"), "{doc:?}");
        if i > 0 {
            // Steps 1+ warm-start from the previous λ's solution.
            assert_eq!(doc.get("warm_started").and_then(|v| v.as_bool()), Some(true), "{doc:?}");
            let (_, _, sse) = req(&addr, "GET", &format!("/v1/jobs/{job}/events"), None);
            assert!(
                sse.contains("\"hit\":true"),
                "warm job {job} must emit a cache-hit probe event:\n{sse}"
            );
        }
    }
    let (status, _, metrics) = req(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let metric = |name: &str| -> f64 {
        metrics
            .lines()
            .find(|l| l.starts_with(name) && !l.starts_with('#'))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("metric {name} missing in:\n{metrics}"))
    };
    assert!(metric("flexa_cache_hits_total") >= 1.0, "the warm sweep must hit the cache");
    assert_eq!(metric("flexa_jobs_submitted_total"), 16.0);
    assert_eq!(metric("flexa_jobs_finished_total{outcome=\"done\"}"), 16.0);
    assert_eq!(metric("flexa_queue_depth"), 0.0);

    let (results, stats) = server.shutdown().expect("clean shutdown");
    assert_eq!(results.len(), 16);
    assert!(stats.hits >= 1);
}

/// A burst beyond the queue capacity returns 429 + Retry-After without
/// wedging any connection, and DELETE mid-run yields a Cancelled
/// terminal event on the SSE stream.
#[test]
fn full_queue_returns_429_and_delete_cancels_midrun() {
    let server = spawn(
        HttpConfig::default(),
        ServeConfig::default().with_workers(1).with_queue_capacity(2).with_cache_bytes(0),
    );
    let addr = server.addr().to_string();

    // Occupy the single worker with a de-facto unbounded job.
    let long = post_job(
        &addr,
        "{\"problem\":\"lasso\",\"rows\":40,\"cols\":120,\"seed\":3,\
         \"max_iters\":50000000,\"target\":0,\"tag\":\"long\"}",
    );
    poll_until_running(&addr, long);

    // Burst: the two queue slots fill, then 429 with Retry-After.
    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";
    let mut rejected = None;
    for _ in 0..6 {
        let (status, headers, body) = req(&addr, "POST", "/v1/jobs", Some(tiny));
        match status {
            202 => continue,
            429 => {
                rejected = Some((headers, body));
                break;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    let (headers, body) = rejected.expect("a burst beyond capacity must see a 429");
    assert!(header(&headers, "retry-after").is_some(), "429 carries Retry-After: {headers:?}");
    assert!(body.contains("queue full"), "{body}");

    // The server is still fully responsive (no deadlocked threads).
    let (status, _, _) = req(&addr, "GET", "/healthz", None);
    assert_eq!(status, 200);

    // Cancel the running job; its SSE stream ends with outcome=cancelled.
    let (status, _, body) = req(&addr, "DELETE", &format!("/v1/jobs/{long}"), None);
    assert_eq!(status, 200, "{body}");
    let (status, _, sse) = req(&addr, "GET", &format!("/v1/jobs/{long}/events"), None);
    assert_eq!(status, 200);
    assert!(sse.contains("event: finished"), "{sse}");
    assert!(sse.contains("\"outcome\":\"cancelled\""), "{sse}");
    let doc = wait_finished(&addr, long);
    assert_eq!(doc.get("outcome").and_then(|v| v.as_str()), Some("cancelled"));

    // Shutdown drains the queued tiny jobs; nothing deadlocks.
    let (results, _) = server.shutdown().expect("clean shutdown");
    assert!(results.len() >= 3, "long job + queued tiny jobs all produced results");
}

/// `serve::jobfile` error paths over HTTP: oversized body → 413,
/// truncated JSON → 400, unknown names → 400 with the registry's typo
/// suggestion, plus 404/405/400 routing edges.
#[test]
fn jobfile_error_paths_surface_as_http_errors() {
    let server = spawn(
        HttpConfig { max_body_bytes: 2048, ..HttpConfig::default() },
        ServeConfig::default().with_workers(1).with_cache_bytes(0),
    );
    let addr = server.addr().to_string();

    // Oversized body → 413 naming the limit.
    let huge = format!("{{\"tag\":\"{}\"}}", "x".repeat(4000));
    let (status, _, body) = req(&addr, "POST", "/v1/jobs", Some(&huge));
    assert_eq!(status, 413, "{body}");
    assert!(body.contains("2048"), "{body}");

    // Truncated JSON → 400 with the parser's complaint.
    let (status, _, body) = req(&addr, "POST", "/v1/jobs", Some("{\"problem\": \"lasso\", \"rows\": 30"));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("error"), "{body}");

    // Unknown solver → 400 carrying the registry's suggestion.
    let (status, _, body) =
        req(&addr, "POST", "/v1/jobs", Some("{\"rows\":20,\"cols\":60,\"algo\":\"fpaa\"}"));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("did you mean `fpa`"), "{body}");

    // Unknown problem → 400 with suggestion.
    let (status, _, body) =
        req(&addr, "POST", "/v1/jobs", Some("{\"problem\":\"laso\",\"rows\":20,\"cols\":60}"));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("did you mean `lasso`"), "{body}");

    // Unknown job key → 400 listing the known keys.
    let (status, _, body) = req(&addr, "POST", "/v1/jobs", Some("{\"rowz\": 10}"));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("unknown job key"), "{body}");

    // Out-of-range kernel-thread requests → 400 naming the valid range.
    let cores = flexa::par::host_cores().min(flexa::par::MAX_POOL_THREADS);
    for bad in [0, cores + 1] {
        let (status, _, body) = req(
            &addr,
            "POST",
            "/v1/jobs",
            Some(&format!("{{\"rows\":20,\"cols\":60,\"threads\":{bad}}}")),
        );
        assert_eq!(status, 400, "{body}");
        assert!(body.contains(&format!("between 1 and {cores}")), "{body}");
    }
    // An in-range request is accepted.
    let (status, _, body) =
        req(&addr, "POST", "/v1/jobs", Some("{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0,\"threads\":1}"));
    assert_eq!(status, 202, "{body}");

    // Routing edges.
    let (status, _, _) = req(&addr, "GET", "/v1/jobs/999999", None);
    assert_eq!(status, 404);
    let (status, _, _) = req(&addr, "DELETE", "/v1/jobs/999999", None);
    assert_eq!(status, 404);
    let (status, _, _) = req(&addr, "GET", "/v1/jobs/999999/events", None);
    assert_eq!(status, 404);
    let (status, _, body) = req(&addr, "GET", "/v1/jobs/not-a-number", None);
    assert_eq!(status, 400, "{body}");
    let (status, _, _) = req(&addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, headers, _) = req(&addr, "PUT", "/v1/jobs", None);
    assert_eq!(status, 405);
    assert_eq!(header(&headers, "allow"), Some("POST"));

    // The failures are visible in the error counter.
    let (_, _, metrics) = req(&addr, "GET", "/metrics", None);
    let errors: f64 = metrics
        .lines()
        .find(|l| l.starts_with("flexa_http_errors_total "))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .expect("error counter present");
    assert!(errors >= 9.0, "all the 4xx responses above are counted: {errors}");
    server.shutdown().expect("clean shutdown");
}

/// Tenant control plane over the wire: bearer auth (401/403), the
/// jobfile `tenant` key rules, per-tenant quota 429s with the tenant's
/// own Retry-After, per-tenant `/metrics` counters, and request-id
/// echo + `Expect: 100-continue` handling on a live socket.
#[test]
fn tenant_auth_quotas_and_request_ids_over_http() {
    use flexa::tenant::{Tenant, TenantQuota, TenantRegistry};
    let tenants = TenantRegistry::new(vec![
        Tenant::new("alice")
            .with_token("alice-secret")
            .with_weight(3)
            .with_retry_after_secs(7),
        Tenant::new("blocked")
            .with_token("blocked-secret")
            .with_quota(TenantQuota::unlimited().with_max_queued(0)),
        Tenant::new("ghost").with_token("ghost-secret").disabled(),
        Tenant::new("open"), // tokenless: selectable via the jobfile key
    ])
    .unwrap();
    let server = spawn(
        HttpConfig::default(),
        ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
    );
    let addr = server.addr().to_string();
    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";
    let auth = |token: &str| vec![("Authorization", token)];

    // Authorized: 202, response names the tenant, job status carries it.
    let (status, headers, body) =
        req_with(&addr, "POST", "/v1/jobs", Some(tiny), &[("Authorization", "Bearer alice-secret")]);
    assert_eq!(status, 202, "{body}");
    assert!(body.contains("\"tenant\":\"alice\""), "{body}");
    assert!(header(&headers, "x-flexa-request-id").is_some(), "request id echoed: {headers:?}");
    let job = Json::parse(&body).unwrap().get("job").unwrap().as_f64().unwrap() as u64;
    // Visibility is tenant-scoped: polling alice's job needs her token.
    let doc = wait_finished_with(&addr, job, &[("Authorization", "Bearer alice-secret")]);
    assert_eq!(doc.get("tenant").and_then(|v| v.as_str()), Some("alice"), "{doc:?}");
    assert_eq!(doc.get("retries").and_then(|v| v.as_f64()), Some(0.0), "{doc:?}");

    // Unknown token → 401 + WWW-Authenticate; disabled tenant → 403.
    let bad = auth("Bearer nope");
    let (status, headers, body) = req_with(&addr, "POST", "/v1/jobs", Some(tiny), &bad);
    assert_eq!(status, 401, "{body}");
    assert!(header(&headers, "www-authenticate").is_some(), "{headers:?}");
    let (status, _, body) =
        req_with(&addr, "POST", "/v1/jobs", Some(tiny), &[("Authorization", "Bearer ghost-secret")]);
    assert_eq!(status, 403, "{body}");
    assert!(body.contains("disabled"), "{body}");

    // Over quota (max_queued = 0 admits nothing): 429 with the default
    // Retry-After for that tenant.
    let (status, headers, body) = req_with(
        &addr,
        "POST",
        "/v1/jobs",
        Some(tiny),
        &[("Authorization", "Bearer blocked-secret")],
    );
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("max_queued"), "{body}");
    assert!(header(&headers, "retry-after").is_some(), "{headers:?}");

    // Jobfile tenant key: a tokenless tenant is selectable without
    // credentials; naming someone else's tenant with a mismatched token
    // is 403; naming a token-protected tenant without auth is 403.
    let spec_open = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0,\"tenant\":\"open\"}";
    let (status, _, body) = req(&addr, "POST", "/v1/jobs", Some(spec_open));
    assert_eq!(status, 202, "{body}");
    assert!(body.contains("\"tenant\":\"open\""), "{body}");
    let spec_alice = "{\"rows\":15,\"cols\":45,\"tenant\":\"alice\"}";
    let (status, _, body) = req(&addr, "POST", "/v1/jobs", Some(spec_alice));
    assert_eq!(status, 403, "{body}");
    assert!(body.contains("requires authentication"), "{body}");
    let (status, _, body) = req_with(
        &addr,
        "POST",
        "/v1/jobs",
        Some(spec_alice),
        &[("Authorization", "Bearer blocked-secret")],
    );
    assert_eq!(status, 403, "{body}");
    assert!(body.contains("authenticates"), "{body}");

    // Request ids are monotonic across requests.
    let id_of = |headers: &[(String, String)]| -> u64 {
        header(headers, "x-flexa-request-id").unwrap().parse().unwrap()
    };
    let (_, h1, _) = req(&addr, "GET", "/healthz", None);
    let (_, h2, _) = req(&addr, "GET", "/healthz", None);
    assert!(id_of(&h2) > id_of(&h1), "{h1:?} then {h2:?}");

    // Expect: 100-continue on a live socket: interim 100, then the 202.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let head = format!(
        "POST /v1/jobs HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\nAuthorization: Bearer alice-secret\r\nExpect: 100-continue\r\nContent-Length: {}\r\n\r\n",
        tiny.len()
    );
    stream.write_all(head.as_bytes()).unwrap();
    stream.write_all(tiny.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let raw = String::from_utf8(raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 100 Continue\r\n\r\n"), "interim first: {raw:.120}");
    assert!(raw.contains("HTTP/1.1 202"), "{raw}");
    // Unsupported expectation → 417.
    let (status, _, body) = req_with(
        &addr,
        "POST",
        "/v1/jobs",
        Some(tiny),
        &[("Authorization", "Bearer alice-secret"), ("Expect", "no-such-expectation")],
    );
    assert_eq!(status, 417, "{body}");

    // Per-tenant metrics families are labeled and counting.
    let (_, _, metrics) = req(&addr, "GET", "/metrics", None);
    for needle in [
        "flexa_tenant_jobs_submitted_total{tenant=\"alice\"}",
        "flexa_tenant_quota_rejected_total{tenant=\"blocked\"} 1",
        "flexa_jobs_quota_rejected_total 1",
    ] {
        assert!(metrics.contains(needle), "missing `{needle}` in:\n{metrics}");
    }

    server.shutdown().expect("clean shutdown");
}

/// Job visibility is tenant-scoped: another tenant's job answers 404 on
/// status, events and DELETE — byte-for-byte the same 404 an id that
/// never existed gets, so ids cannot be probed across tenants. The
/// owner (and only the owner) still sees everything.
#[test]
fn job_visibility_is_scoped_to_the_owning_tenant() {
    use flexa::tenant::{Tenant, TenantRegistry};
    let tenants = TenantRegistry::new(vec![
        Tenant::new("alice").with_token("alice-secret"),
        Tenant::new("bob").with_token("bob-secret"),
    ])
    .unwrap();
    let server = spawn(
        HttpConfig::default(),
        ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
    );
    let addr = server.addr().to_string();
    let alice = [("Authorization", "Bearer alice-secret")];
    let bob = [("Authorization", "Bearer bob-secret")];

    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";
    let (status, _, body) = req_with(&addr, "POST", "/v1/jobs", Some(tiny), &alice);
    assert_eq!(status, 202, "{body}");
    let job = Json::parse(&body).unwrap().get("job").unwrap().as_f64().unwrap() as u64;
    wait_finished_with(&addr, job, &alice);

    // Bob sees alice's job exactly as he sees a never-submitted id.
    let (foreign_status, _, foreign_body) =
        req_with(&addr, "GET", &format!("/v1/jobs/{job}"), None, &bob);
    let (ghost_status, _, ghost_body) =
        req_with(&addr, "GET", &format!("/v1/jobs/{}", job + 100_000), None, &bob);
    assert_eq!(foreign_status, 404, "{foreign_body}");
    assert_eq!(ghost_status, 404);
    assert_eq!(
        foreign_body.replace(&job.to_string(), "ID"),
        ghost_body.replace(&(job + 100_000).to_string(), "ID"),
        "a foreign job must be indistinguishable from a nonexistent one"
    );

    // Same 404 for DELETE and the SSE stream — and nothing got cancelled.
    let (status, _, body) = req_with(&addr, "DELETE", &format!("/v1/jobs/{job}"), None, &bob);
    assert_eq!(status, 404, "{body}");
    let (status, _, body) =
        req_with(&addr, "GET", &format!("/v1/jobs/{job}/events"), None, &bob);
    assert_eq!(status, 404, "{body}");

    // The anonymous `default` tenant doesn't see alice's job either.
    let (status, _, _) = req(&addr, "GET", &format!("/v1/jobs/{job}"), None);
    assert_eq!(status, 404);

    // The owner still has full access: status, events, delete.
    let doc = wait_finished_with(&addr, job, &alice);
    assert_eq!(doc.get("tenant").and_then(|v| v.as_str()), Some("alice"));
    let (status, _, sse) =
        req_with(&addr, "GET", &format!("/v1/jobs/{job}/events"), None, &alice);
    assert_eq!(status, 200);
    assert!(sse.contains("event: finished"), "{sse}");
    let (status, _, body) = req_with(&addr, "DELETE", &format!("/v1/jobs/{job}"), None, &alice);
    assert_eq!(status, 200, "cancel of a finished own job is a no-op 200: {body}");

    server.shutdown().expect("clean shutdown");
}

/// 429 `Retry-After` is rounded *up* and never 0: a tenant configured
/// with `retry_after_secs = 0` (or a server with a zero queue-full
/// backoff) still advertises `Retry-After: 1` while throttled.
#[test]
fn retry_after_on_429_never_advertises_zero() {
    use flexa::tenant::{Tenant, TenantQuota, TenantRegistry};
    let tenants = TenantRegistry::new(vec![Tenant::new("zero")
        .with_token("zero-secret")
        .with_retry_after_secs(0)
        .with_quota(TenantQuota::unlimited().with_max_queued(0))])
    .unwrap();
    let server = spawn(
        HttpConfig { retry_after_secs: 0, ..HttpConfig::default() },
        ServeConfig::default()
            .with_workers(1)
            .with_queue_capacity(1)
            .with_cache_bytes(0)
            .with_tenants(tenants),
    );
    let addr = server.addr().to_string();
    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";

    // Quota arm: max_queued = 0 refuses immediately; the tenant's
    // retry_after_secs of 0 must surface as `Retry-After: 1`.
    let (status, headers, body) =
        req_with(&addr, "POST", "/v1/jobs", Some(tiny), &[("Authorization", "Bearer zero-secret")]);
    assert_eq!(status, 429, "{body}");
    assert_eq!(header(&headers, "retry-after"), Some("1"), "{headers:?}");

    // Queue-full arm: occupy the worker, fill the single queue slot,
    // then overflow — the server's retry_after_secs of 0 also clamps.
    let long = post_job(
        &addr,
        "{\"problem\":\"lasso\",\"rows\":40,\"cols\":120,\"seed\":3,\
         \"max_iters\":50000000,\"target\":0,\"tag\":\"long\"}",
    );
    poll_until_running(&addr, long);
    let mut clamped = None;
    for _ in 0..4 {
        let (status, headers, body) = req(&addr, "POST", "/v1/jobs", Some(tiny));
        match status {
            202 => continue,
            429 => {
                assert!(body.contains("queue full"), "{body}");
                clamped = Some(header(&headers, "retry-after").unwrap().to_string());
                break;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert_eq!(clamped.as_deref(), Some("1"), "queue-full Retry-After clamps to 1");

    let (status, _, body) = req(&addr, "DELETE", &format!("/v1/jobs/{long}"), None);
    assert_eq!(status, 200, "{body}");
    server.shutdown().expect("clean shutdown");
}

/// Keep-alive works (two exchanges on one connection), /healthz and
/// /v1/registry respond, and the registry JSON carries descriptions.
#[test]
fn keep_alive_healthz_and_registry() {
    let server = spawn(HttpConfig::default(), ServeConfig::default().with_workers(1));
    let addr = server.addr().to_string();

    // Two requests over one connection.
    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for i in 0..2 {
        writer
            .write_all(format!("GET /healthz HTTP/1.1\r\nHost: {addr}\r\n\r\n").as_bytes())
            .unwrap();
        let (status, headers, body) = read_one_response(&mut reader);
        assert_eq!(status, 200, "request {i} on the shared connection");
        assert_eq!(header(&headers, "connection"), Some("keep-alive"));
        assert_eq!(body, "{\"status\":\"ok\"}");
    }

    let (status, _, body) = req(&addr, "GET", "/v1/registry", None);
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("registry json parses");
    let Some(Json::Arr(problems)) = doc.get("problems") else { panic!("{body}") };
    assert!(problems
        .iter()
        .any(|p| p.get("name").and_then(|v| v.as_str()) == Some("lasso")));
    let Some(Json::Arr(solvers)) = doc.get("solvers") else { panic!("{body}") };
    let fpa = solvers
        .iter()
        .find(|s| s.get("name").and_then(|v| v.as_str()) == Some("fpa"))
        .expect("fpa listed");
    assert!(fpa.get("about").and_then(|v| v.as_str()).unwrap_or("").contains("FLEXA"));

    server.shutdown().expect("clean shutdown");
}

/// Read exactly one response off a keep-alive connection (headers +
/// Content-Length body).
fn read_one_response(reader: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, String) {
    let mut head = String::new();
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "connection closed mid-response");
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let (status, headers, _) = parse_response(&format!("{head}\r\n"));
    let len: usize = header(&headers, "content-length").unwrap().parse().unwrap();
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, headers, String::from_utf8(body).unwrap())
}

/// Queue-full and quota 429s advertise a Retry-After derived from the
/// *observed* completion rate once one exists, not the configured
/// constants: with 60 s constants on both paths and a few quick
/// completions on record, the advertised wait is the slot estimate
/// (seconds at most), still rounded up and never 0.
#[test]
fn retry_after_derives_from_observed_service_rate() {
    use flexa::tenant::{Tenant, TenantQuota, TenantRegistry};
    let tenants = TenantRegistry::new(vec![Tenant::new("walled")
        .with_token("walled-secret")
        .with_retry_after_secs(60)
        .with_quota(TenantQuota::unlimited().with_max_queued(0))])
    .unwrap();
    let server = spawn(
        HttpConfig { retry_after_secs: 60, ..HttpConfig::default() },
        ServeConfig::default()
            .with_workers(1)
            .with_queue_capacity(1)
            .with_cache_bytes(0)
            .with_tenants(tenants),
    );
    let addr = server.addr().to_string();
    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";

    // Put a service rate on record: three quick completions.
    for _ in 0..3 {
        let job = post_job(&addr, tiny);
        wait_finished(&addr, job);
    }

    // Quota arm: max_queued = 0 refuses immediately, but the advertised
    // wait comes from the observed rate, not the tenant's 60 s constant.
    let (status, headers, body) = req_with(
        &addr,
        "POST",
        "/v1/jobs",
        Some(tiny),
        &[("Authorization", "Bearer walled-secret")],
    );
    assert_eq!(status, 429, "{body}");
    let advertised: u64 = header(&headers, "retry-after").unwrap().parse().unwrap();
    assert!(
        (1..60).contains(&advertised),
        "quota Retry-After should be rate-derived (>=1, well under the 60s constant), got {advertised}"
    );

    // Queue-full arm: occupy the worker, fill the single slot, overflow.
    let long = post_job(
        &addr,
        "{\"problem\":\"lasso\",\"rows\":40,\"cols\":120,\"seed\":3,\
         \"max_iters\":50000000,\"target\":0,\"tag\":\"long\"}",
    );
    poll_until_running(&addr, long);
    let mut advertised = None;
    for _ in 0..4 {
        let (status, headers, body) = req(&addr, "POST", "/v1/jobs", Some(tiny));
        match status {
            202 => continue,
            429 => {
                assert!(body.contains("queue full"), "{body}");
                advertised =
                    Some(header(&headers, "retry-after").unwrap().parse::<u64>().unwrap());
                break;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    let advertised = advertised.expect("queue never overflowed");
    assert!(
        (1..60).contains(&advertised),
        "queue-full Retry-After should be rate-derived, got {advertised}"
    );

    let (status, _, body) = req(&addr, "DELETE", &format!("/v1/jobs/{long}"), None);
    assert_eq!(status, 200, "{body}");
    server.shutdown().expect("clean shutdown");
}

/// Per-tenant rate limiting over HTTP: the burst admits back-to-back
/// submissions, the next gets `429` with an *accurate* token-accrual
/// Retry-After, and the refusal shows up in `/metrics` both per tenant
/// (`flexa_tenant_rate_limited_total`) and globally.
#[test]
fn rate_limited_tenant_gets_429_with_accurate_retry_after_and_metrics() {
    use flexa::tenant::{RateLimit, Tenant, TenantRegistry};
    let tenants = TenantRegistry::new(vec![Tenant::new("metered")
        .with_token("metered-secret")
        .with_rate_limit(RateLimit::per_sec(0.05).with_burst(2.0))])
    .unwrap();
    let server = spawn(
        HttpConfig::default(),
        ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
    );
    let addr = server.addr().to_string();
    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";
    let auth = [("Authorization", "Bearer metered-secret")];

    // Burst of 2 admits two back-to-back submissions.
    for i in 0..2 {
        let (status, _, body) = req_with(&addr, "POST", "/v1/jobs", Some(tiny), &auth);
        assert_eq!(status, 202, "burst submission {i}: {body}");
    }
    // The third refuses: one token at 0.05/s accrues in 20 s, so the
    // advertised wait is in (0, 20] seconds — and never 0.
    let (status, headers, body) = req_with(&addr, "POST", "/v1/jobs", Some(tiny), &auth);
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("rate limit"), "{body}");
    let advertised: u64 = header(&headers, "retry-after").unwrap().parse().unwrap();
    assert!(
        (1..=20).contains(&advertised),
        "token accrual at 0.05/s is at most 20s, got {advertised}"
    );

    // The refusal is visible in /metrics, per tenant and globally.
    let (status, _, metrics) = req(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(
        metrics.contains("flexa_tenant_rate_limited_total{tenant=\"metered\"} 1"),
        "{metrics}"
    );
    assert!(metrics.contains("flexa_jobs_rate_limited_total 1"), "{metrics}");
    server.shutdown().expect("clean shutdown");
}

/// Backpressure: a stalled `GET /v1/jobs/{id}/events` consumer — one
/// that sends the request and then never reads — must not block the
/// scheduler, the control plane, or a healthy subscriber on the same
/// job. The event hub fans out with bounded `try_send` buffers, so the
/// stalled connection's thread blocks on its own socket while everything
/// else proceeds; and the replay log stays bounded: a late subscriber
/// gets exactly the first `sse_iteration_retention` iteration events
/// plus a truncation notice, never the full multi-thousand-event run.
#[test]
fn stalled_sse_reader_does_not_block_scheduler_or_other_subscribers() {
    let server = spawn(
        HttpConfig { access_log: false, sse_iteration_retention: 5, ..HttpConfig::default() },
        ServeConfig::default().with_workers(2).with_cache_bytes(0),
    );
    let addr = server.addr().to_string();

    // A de-facto unbounded job emitting a fast iteration stream.
    let long = post_job(
        &addr,
        "{\"problem\":\"lasso\",\"rows\":40,\"cols\":120,\"seed\":3,\
         \"max_iters\":50000000,\"target\":0,\"tag\":\"long\"}",
    );
    poll_until_running(&addr, long);

    // The stalled consumer: subscribe, then never read a byte. The SSE
    // writer fills the socket buffers and blocks its connection thread.
    let stalled = TcpStream::connect(&addr).expect("connect stalled reader");
    (&stalled)
        .write_all(
            format!(
                "GET /v1/jobs/{long}/events HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
            )
            .as_bytes(),
        )
        .unwrap();
    std::thread::sleep(Duration::from_millis(200));

    // A healthy subscriber alongside still receives fresh live frames.
    let live = TcpStream::connect(&addr).expect("connect live reader");
    live.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    (&live)
        .write_all(
            format!(
                "GET /v1/jobs/{long}/events HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
            )
            .as_bytes(),
        )
        .unwrap();
    let mut reader = BufReader::new(live);
    let mut seen_iterations = 0;
    let mut line = String::new();
    while seen_iterations < 3 {
        line.clear();
        let n = reader.read_line(&mut line).expect("live SSE stream stays readable");
        assert!(n > 0, "live SSE stream ended before delivering iterations");
        if line.starts_with("event: iteration") {
            seen_iterations += 1;
        }
    }

    // The scheduler still dispatches new work while the stalled reader
    // is pinned, and the control plane still answers.
    let short =
        post_job(&addr, "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0,\"tag\":\"short\"}");
    let doc = wait_finished(&addr, short);
    assert_eq!(doc.get("outcome").and_then(|v| v.as_str()), Some("done"), "{doc:?}");
    let (status, _, _) = req(&addr, "GET", "/healthz", None);
    assert_eq!(status, 200);

    // Cancel the long job and replay it late: the bounded log kept only
    // the FIRST `sse_iteration_retention` iteration events and says so.
    let (status, _, body) = req(&addr, "DELETE", &format!("/v1/jobs/{long}"), None);
    assert_eq!(status, 200, "{body}");
    wait_finished(&addr, long);
    let (status, _, sse) = req(&addr, "GET", &format!("/v1/jobs/{long}/events"), None);
    assert_eq!(status, 200);
    assert_eq!(
        sse.matches("event: iteration").count(),
        5,
        "replay keeps exactly sse_iteration_retention iterations:\n{sse}"
    );
    assert!(sse.contains("replay truncated"), "{sse}");
    assert!(sse.contains("event: finished"), "{sse}");
    assert!(sse.contains("\"outcome\":\"cancelled\""), "{sse}");

    // Release the stalled socket so its blocked writer errors out, then
    // shut down; a hung connection thread would hang the drain here.
    stalled.shutdown(std::net::Shutdown::Both).ok();
    drop(stalled);
    drop(reader);
    let (results, _) = server.shutdown().expect("clean shutdown despite the stalled consumer");
    assert!(results.len() >= 2, "long + short jobs produced results");
}
