//! Property-based tests (via the in-crate mini-proptest substrate) over
//! solver/coordinator invariants: selection correctness, best-response
//! optimality, fixed-point characterization, sharding, generators.

use flexa::coordinator::ShardPlan;
use flexa::datagen::NesterovLasso;
use flexa::linalg::{ops, DenseMatrix, MatVec};
use flexa::problems::lasso::Lasso;
use flexa::problems::{BlockLayout, CompositeProblem};
use flexa::proptest::{assert_close, run_prop, CaseResult, PropConfig};
use flexa::select::{SelectionRule, Selector};

/// S.3 invariant (Theorem 1's condition): every selection rule returns a
/// set containing at least one index with E_i >= rho * max E (rho = 1 for
/// the max itself).
#[test]
fn prop_selection_contains_near_max_block() {
    run_prop("selection-near-max", PropConfig::default(), |rng, size| {
        let nb = 1 + rng.next_below(8 * size as u64 + 4) as usize;
        let mut e = vec![0.0; nb];
        rng.fill_uniform(&mut e, 0.0, 1.0);
        let rules = [
            SelectionRule::FullJacobi,
            SelectionRule::GreedyRho { rho: 0.5 },
            SelectionRule::GreedyRho { rho: 1.0 },
            SelectionRule::GaussSouthwell,
            SelectionRule::TopP { p: 1 + rng.next_below(nb as u64) as usize },
            SelectionRule::Cyclic { batch: 1 + rng.next_below(nb as u64) as usize },
            SelectionRule::Random { count: 1 + rng.next_below(nb as u64) as usize, seed: rng.next_u64() },
        ];
        let max_e = e.iter().cloned().fold(0.0, f64::max);
        for rule in rules {
            let mut sel = Selector::new(rule.clone());
            let mut mask = vec![false; nb];
            let count = sel.select(&e, &mut mask);
            if count == 0 || !mask.iter().any(|&b| b) {
                return CaseResult::Fail(format!("{rule:?}: empty selection"));
            }
            if count != mask.iter().filter(|&&b| b).count() {
                return CaseResult::Fail(format!("{rule:?}: count mismatch"));
            }
            // Theorem condition with rho = 1 (max included) or the rule's rho.
            let has_near_max = mask
                .iter()
                .enumerate()
                .any(|(i, &b)| b && e[i] >= 0.5 * max_e);
            if !has_near_max && max_e > 0.0 {
                return CaseResult::Fail(format!("{rule:?}: no near-max block selected"));
            }
        }
        CaseResult::Pass
    });
}

/// Theorem 1's condition, exactly: every selection rule's returned mask
/// contains at least one index *attaining* `max_i E_i` — not merely a
/// near-max block — across random error vectors (including ties, zeros
/// and degenerate all-zero E) and all six rules.
#[test]
fn prop_selection_always_contains_argmax_block() {
    run_prop("selection-argmax", PropConfig::default(), |rng, size| {
        let nb = 1 + rng.next_below(8 * size as u64 + 4) as usize;
        let mut e = vec![0.0; nb];
        rng.fill_uniform(&mut e, 0.0, 1.0);
        // Stress ties and zeros: sometimes zero a prefix, sometimes
        // duplicate the maximum into another slot.
        if nb > 1 && rng.next_f64() < 0.3 {
            let zeros = rng.next_below(nb as u64) as usize;
            for v in e.iter_mut().take(zeros) {
                *v = 0.0;
            }
        }
        if nb > 1 && rng.next_f64() < 0.3 {
            let max = e.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let dup = rng.next_below(nb as u64) as usize;
            e[dup] = max;
        }
        let max_e = e.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let rules = [
            SelectionRule::FullJacobi,
            SelectionRule::GreedyRho { rho: 0.5 },
            SelectionRule::GreedyRho { rho: 1.0 },
            SelectionRule::GaussSouthwell,
            SelectionRule::TopP { p: 1 + rng.next_below(nb as u64) as usize },
            SelectionRule::Cyclic { batch: 1 + rng.next_below(nb as u64) as usize },
            SelectionRule::Random {
                count: 1 + rng.next_below(nb as u64) as usize,
                seed: rng.next_u64(),
            },
        ];
        for rule in rules {
            let mut sel = Selector::new(rule.clone());
            let mut mask = vec![false; nb];
            sel.select(&e, &mut mask);
            let has_argmax = mask.iter().enumerate().any(|(i, &b)| b && e[i] == max_e);
            if !has_argmax {
                return CaseResult::Fail(format!(
                    "{rule:?}: selected set contains no argmax block (max E = {max_e:.6}, e = {e:?})"
                ));
            }
        }
        CaseResult::Pass
    });
}

/// The scalar best-response is the exact minimizer of the block
/// surrogate h̃ (paper eq. (2)): random perturbations never improve it.
#[test]
fn prop_best_response_minimizes_surrogate() {
    run_prop("br-optimality", PropConfig::default(), |rng, size| {
        let (x, g) = (rng.normal(0.0, 2.0), rng.normal(0.0, 5.0));
        let d = 0.1 + rng.next_f64() * 10.0 * size as f64;
        let tau = 0.1 + rng.next_f64() * 5.0;
        let c = 0.05 + rng.next_f64() * 3.0;
        let denom = d + tau;
        let z = ops::soft_threshold(x - g / denom, c / denom);
        // Surrogate: g*(z-x) + (d+tau)/2 (z-x)^2 + c|z|.
        let h = |z: f64| g * (z - x) + 0.5 * denom * (z - x) * (z - x) + c * z.abs();
        let base = h(z);
        for _ in 0..20 {
            let dz = rng.normal(0.0, 0.5);
            if h(z + dz) < base - 1e-10 {
                return CaseResult::Fail(format!(
                    "perturbation improved surrogate: h({})={} < h({z})={base}",
                    z + dz,
                    h(z + dz)
                ));
            }
        }
        CaseResult::Pass
    });
}

/// Fixed points of the best-response map are exactly the KKT points
/// (Prop. 3(b)): on planted instances, x* is a fixed point for any tau.
#[test]
fn prop_planted_solution_is_fixed_point() {
    run_prop("xstar-fixed-point", PropConfig { cases: 16, seed: 0xF1E7A }, |rng, size| {
        let m = 10 + 3 * size;
        let n = 3 * m;
        let inst = NesterovLasso::new(m, n, 0.1, 0.5 + rng.next_f64()).seed(rng.next_u64()).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c);
        let tau = 0.5 + rng.next_f64() * 10.0;
        let mut g = vec![0.0; n];
        p.grad_smooth(&inst.x_star, &mut g);
        let mut d = vec![0.0; n];
        p.curvature(&inst.x_star, &mut d);
        let mut z = vec![0.0; n];
        for j in 0..n {
            let denom = d[j] + tau;
            z[j] = ops::soft_threshold(inst.x_star[j] - g[j] / denom, inst.c / denom);
        }
        assert_close(&z, &inst.x_star, 1e-7, "best response at x*")
    });
}

/// Shard plans: disjoint cover, preserved order, near-balanced.
#[test]
fn prop_shard_plan_partitions() {
    run_prop("shard-partition", PropConfig::default(), |rng, size| {
        let n = 1 + rng.next_below(200 * size as u64 + 10) as usize;
        let bs = 1 + rng.next_below(7) as usize;
        let layout = BlockLayout::uniform(n, bs);
        let workers = 1 + rng.next_below(17) as usize;
        let plan = ShardPlan::balanced(&layout, workers);
        let mut covered = vec![false; layout.num_blocks()];
        let mut prev_end = 0usize;
        for w in 0..plan.workers() {
            let blocks = plan.blocks(w);
            if blocks.start != prev_end {
                return CaseResult::Fail(format!("gap at worker {w}"));
            }
            prev_end = blocks.end;
            for b in blocks {
                if covered[b] {
                    return CaseResult::Fail(format!("block {b} covered twice"));
                }
                covered[b] = true;
            }
        }
        if prev_end != layout.num_blocks() || !covered.iter().all(|&b| b) {
            return CaseResult::Fail("incomplete cover".into());
        }
        CaseResult::Pass
    });
}

/// Nesterov instances: KKT certificate holds for every generated
/// configuration (the relative-error metric depends on it).
#[test]
fn prop_generator_kkt() {
    run_prop("nesterov-kkt", PropConfig { cases: 12, seed: 7 }, |rng, size| {
        let m = 8 + 4 * size;
        let n = 2 * m + rng.next_below(m as u64) as usize;
        let sp = [0.05, 0.1, 0.2, 0.5][rng.next_below(4) as usize];
        let c = 0.3 + 2.0 * rng.next_f64();
        let inst = NesterovLasso::new(m, n, sp, c).seed(rng.next_u64()).generate();
        let p = Lasso::new(inst.a.clone(), inst.b.clone(), inst.c);
        let mut g = vec![0.0; n];
        p.grad_smooth(&inst.x_star, &mut g);
        for j in 0..n {
            if inst.x_star[j] != 0.0 {
                let want = -c * inst.x_star[j].signum();
                if (g[j] - want).abs() > 1e-7 * (1.0 + c) {
                    return CaseResult::Fail(format!("support KKT at {j}: {} vs {want}", g[j]));
                }
            } else if g[j].abs() > c + 1e-7 {
                return CaseResult::Fail(format!("off-support KKT at {j}: |{}| > {c}", g[j]));
            }
        }
        // V* is the objective at x*.
        let v = p.objective(&inst.x_star);
        if (v - inst.v_star).abs() > 1e-8 * v.abs().max(1.0) {
            return CaseResult::Fail(format!("v* mismatch: {v} vs {}", inst.v_star));
        }
        CaseResult::Pass
    });
}

/// Dense and sparse storage produce identical operator behaviour.
#[test]
fn prop_dense_sparse_parity() {
    run_prop("dense-sparse-parity", PropConfig::default(), |rng, size| {
        let m = 2 + rng.next_below(10 * size as u64 + 5) as usize;
        let n = 2 + rng.next_below(10 * size as u64 + 5) as usize;
        let mut dense = DenseMatrix::randn(m, n, rng);
        for j in 0..n {
            for i in 0..m {
                if rng.next_f64() < 0.6 {
                    dense.set(i, j, 0.0);
                }
            }
        }
        let sparse = flexa::linalg::CscMatrix::from_dense(&dense, 0.0);
        let mut x = vec![0.0; n];
        rng.fill_normal(&mut x);
        let (mut yd, mut ys) = (vec![0.0; m], vec![0.0; m]);
        dense.matvec(&x, &mut yd);
        sparse.matvec(&x, &mut ys);
        if let CaseResult::Fail(msg) = assert_close(&yd, &ys, 1e-10, "matvec") {
            return CaseResult::Fail(msg);
        }
        let mut r = vec![0.0; m];
        rng.fill_normal(&mut r);
        let (mut gd, mut gs) = (vec![0.0; n], vec![0.0; n]);
        dense.matvec_t(&r, &mut gd);
        sparse.matvec_t(&r, &mut gs);
        assert_close(&gd, &gs, 1e-10, "matvec_t")
    });
}

/// The FPA iterate stays bounded (coercivity + safeguards): run a short
/// solve from random starts on random instances and check no blow-up.
#[test]
fn prop_fpa_iterates_bounded() {
    use flexa::algos::fpa::Fpa;
    use flexa::algos::{SolveOptions, Solver};
    run_prop("fpa-bounded", PropConfig { cases: 8, seed: 11 }, |rng, size| {
        let m = 15 + 5 * size;
        let n = 2 * m;
        let inst = NesterovLasso::new(m, n, 0.2, 1.0).seed(rng.next_u64()).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut x0 = vec![0.0; n];
        rng.fill_normal(&mut x0);
        let report = Fpa::paper_defaults(&p).solve(
            &p,
            &SolveOptions::default().with_max_iters(300).with_target(0.0).with_x0(x0),
        );
        let norm = ops::nrm2(&report.x);
        CaseResult::check(norm.is_finite() && norm < 1e4, || {
            format!("iterate blew up: ‖x‖ = {norm}")
        })
    });
}

/// Dense `matvec`/`matvec_t` vs a naive triple-loop oracle over random
/// shapes — cols % 4 ∈ {0,1,2,3}, degenerate rows = 0 / cols = 0 — and,
/// on the same draws, bit-identity of the `flexa::par` kernels across
/// thread budgets (shapes large enough here do engage the chunked
/// paths).
#[test]
fn prop_dense_matvec_matches_naive_oracle() {
    use flexa::par;
    run_prop("dense-matvec-oracle", PropConfig { cases: 48, seed: 0xA17 }, |rng, size| {
        // Shapes ramp to ~200x200 (chunked paths engage) and may be 0.
        let rows = rng.next_below(8 * size as u64 + 5) as usize;
        let cols = rng.next_below(8 * size as u64 + 5) as usize;
        let a = DenseMatrix::from_fn(rows, cols, |_, _| rng.next_normal());
        let mut x = vec![0.0; cols];
        rng.fill_uniform(&mut x, -2.0, 2.0);
        let mut r = vec![0.0; rows];
        rng.fill_uniform(&mut r, -2.0, 2.0);

        // Naive triple-loop oracle.
        let mut y_oracle = vec![0.0; rows];
        for (i, yo) in y_oracle.iter_mut().enumerate() {
            let mut s = 0.0;
            for (j, xj) in x.iter().enumerate() {
                s += a.get(i, j) * xj;
            }
            *yo = s;
        }
        let mut g_oracle = vec![0.0; cols];
        for (j, go) in g_oracle.iter_mut().enumerate() {
            let mut s = 0.0;
            for (i, ri) in r.iter().enumerate() {
                s += a.get(i, j) * ri;
            }
            *go = s;
        }

        let run = |threads: usize| {
            par::with_threads(threads, || {
                let mut y = vec![0.0; rows];
                a.matvec(&x, &mut y);
                let mut g = vec![0.0; cols];
                a.matvec_t(&r, &mut g);
                (y, g)
            })
        };
        let (y1, g1) = run(1);
        if let CaseResult::Fail(msg) = assert_close(&y1, &y_oracle, 1e-10, "matvec vs oracle") {
            return CaseResult::Fail(format!("{rows}x{cols}: {msg}"));
        }
        if let CaseResult::Fail(msg) = assert_close(&g1, &g_oracle, 1e-10, "matvec_t vs oracle") {
            return CaseResult::Fail(format!("{rows}x{cols}: {msg}"));
        }
        for threads in [2usize, 4, 8] {
            let (yt, gt) = run(threads);
            let same = y1.iter().zip(&yt).all(|(p, q)| p.to_bits() == q.to_bits())
                && g1.iter().zip(&gt).all(|(p, q)| p.to_bits() == q.to_bits());
            if !same {
                return CaseResult::Fail(format!(
                    "{rows}x{cols}: kernel bits differ between 1 and {threads} threads"
                ));
            }
        }
        CaseResult::Pass
    });
}

/// The explicit edge shapes the oracle property may not hit every run:
/// empty matrices (0×k, k×0) and every cols % 4 tail length.
#[test]
fn dense_matvec_edge_shapes_match_oracle() {
    for (rows, cols) in [(0usize, 4usize), (4, 0), (0, 0), (3, 5), (5, 3), (6, 7), (2, 8), (7, 9)] {
        let a = DenseMatrix::from_fn(rows, cols, |i, j| (i as f64 + 1.0) * 0.5 - (j as f64) * 0.25);
        let x: Vec<f64> = (0..cols).map(|j| (j as f64).cos()).collect();
        let mut y = vec![0.0; rows];
        a.matvec(&x, &mut y);
        for (i, &yi) in y.iter().enumerate() {
            let want: f64 = (0..cols).map(|j| a.get(i, j) * x[j]).sum();
            assert!((yi - want).abs() < 1e-12, "{rows}x{cols} matvec row {i}: {yi} vs {want}");
        }
        let r: Vec<f64> = (0..rows).map(|i| (i as f64).sin()).collect();
        let mut g = vec![0.0; cols];
        a.matvec_t(&r, &mut g);
        for (j, &gj) in g.iter().enumerate() {
            let want: f64 = (0..rows).map(|i| a.get(i, j) * r[i]).sum();
            assert!((gj - want).abs() < 1e-12, "{rows}x{cols} matvec_t col {j}: {gj} vs {want}");
        }
    }
}

/// CSC chunked matvec: bit-identical across thread budgets on a shape
/// wide enough to engage the per-chunk-partials path, and close to the
/// dense result.
#[test]
fn csc_matvec_thread_invariant_on_chunked_shapes() {
    use flexa::linalg::CscMatrix;
    use flexa::par;
    let mut rng = flexa::prng::Xoshiro256pp::seed_from_u64(77);
    // 600 columns -> 2 chunks at the fixed 256-column granularity.
    let (m, n) = (50usize, 600usize);
    let mut d = DenseMatrix::zeros(m, n);
    for j in 0..n {
        for i in 0..m {
            if rng.next_f64() < 0.15 {
                d.set(i, j, rng.next_normal());
            }
        }
    }
    let s = CscMatrix::from_dense(&d, 0.0);
    let mut x = vec![0.0; n];
    rng.fill_normal(&mut x);
    let run = |threads: usize| {
        par::with_threads(threads, || {
            let mut y = vec![0.0; m];
            s.matvec(&x, &mut y);
            y
        })
    };
    let y1 = run(1);
    for threads in [2usize, 4, 8] {
        let yt = run(threads);
        assert!(
            y1.iter().zip(&yt).all(|(p, q)| p.to_bits() == q.to_bits()),
            "CSC matvec bits differ between 1 and {threads} threads"
        );
    }
    let mut yd = vec![0.0; m];
    d.matvec(&x, &mut yd);
    for i in 0..m {
        assert!((y1[i] - yd[i]).abs() < 1e-10, "row {i}: sparse {} vs dense {}", y1[i], yd[i]);
    }
}

/// DRR dispatch under tenant churn: tenants are enabled/disabled and
/// re-weighted mid-stream while pushes and pops interleave, mirrored
/// against a per-tenant FIFO model. Invariants checked on every step:
/// nothing is ever lost or reordered within a tenant (the submission-seq
/// tie-break), a disabled tenant is never served, and `pop_where`
/// returns `None` only when every queued lane is ineligible. A final
/// full drain with everyone re-enabled pins starvation-freedom: no
/// tenant with queued work waits more than one full round (the weight
/// sum) between services. The whole scenario is a pure function of its
/// seed — it is run twice and the two pop traces must be identical.
#[test]
fn prop_drr_queue_survives_tenant_churn() {
    use flexa::prng::Xoshiro256pp;
    use flexa::tenant::DrrQueue;
    use std::collections::{BTreeMap, VecDeque};

    const TENANTS: [&str; 4] = ["a", "b", "c", "d"];

    fn run_churn(seed: u64, steps: usize) -> Result<Vec<(String, u64)>, String> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut q: DrrQueue<u64> = DrrQueue::new();
        let mut model: BTreeMap<&str, VecDeque<u64>> =
            TENANTS.iter().map(|t| (*t, VecDeque::new())).collect();
        let mut weights: BTreeMap<&str, u64> = BTreeMap::new();
        let mut enabled: BTreeMap<&str, bool> = TENANTS.iter().map(|t| (*t, true)).collect();
        for (i, t) in TENANTS.iter().enumerate() {
            q.set_weight(t, i as u64 + 1);
            weights.insert(t, i as u64 + 1);
        }
        let mut seq = 0u64;
        let mut trace = Vec::new();
        for _ in 0..steps {
            let t = TENANTS[rng.next_below(TENANTS.len() as u64) as usize];
            match rng.next_below(10) {
                // Pushes dominate so the drain phase has a real backlog.
                0..=4 => {
                    q.push(t, seq);
                    model.get_mut(t).unwrap().push_back(seq);
                    seq += 1;
                }
                5..=7 => match q.pop_where(|tenant, _| enabled[tenant]) {
                    Some((tenant, item)) => {
                        if !enabled[tenant.as_str()] {
                            return Err(format!("disabled tenant `{tenant}` was served"));
                        }
                        let expect = model.get_mut(tenant.as_str()).unwrap().pop_front();
                        if expect != Some(item) {
                            return Err(format!(
                                "tenant `{tenant}` FIFO broken: popped {item}, model head {expect:?}"
                            ));
                        }
                        trace.push((tenant, item));
                    }
                    None => {
                        for (mt, lane) in &model {
                            if !lane.is_empty() && enabled[mt] {
                                return Err(format!(
                                    "pop_where refused enabled tenant `{mt}` with {} queued",
                                    lane.len()
                                ));
                            }
                        }
                    }
                },
                8 => {
                    let w = rng.next_below(5);
                    q.set_weight(t, w);
                    weights.insert(t, w.max(1)); // the queue clamps 0 to 1
                }
                _ => {
                    let e = enabled.get_mut(t).unwrap();
                    *e = !*e;
                }
            }
            let total: usize = model.values().map(|l| l.len()).sum();
            if q.len() != total {
                return Err(format!("len {} != model total {total}", q.len()));
            }
            for t in &TENANTS {
                if q.queued_for(t) != model[t].len() {
                    return Err(format!(
                        "queued_for({t}) = {} != model {}",
                        q.queued_for(t),
                        model[t].len()
                    ));
                }
            }
        }
        // Drain with everyone re-enabled and weights frozen. DRR grants
        // each active tenant `weight` pops per round, so between two
        // services of one backlogged tenant at most one full round
        // (the weight sum) of other pops can pass.
        let bound: usize = weights.values().sum::<u64>() as usize;
        let mut last_pos: BTreeMap<String, usize> = BTreeMap::new();
        let mut pos = 0usize;
        while let Some((tenant, item)) = q.pop() {
            pos += 1;
            let expect = model.get_mut(tenant.as_str()).unwrap().pop_front();
            if expect != Some(item) {
                return Err(format!(
                    "drain: tenant `{tenant}` FIFO broken: popped {item}, model head {expect:?}"
                ));
            }
            let since = last_pos.get(&tenant).copied().unwrap_or(0);
            if pos - since > bound {
                return Err(format!(
                    "starvation: tenant `{tenant}` waited {} pops (round bound {bound})",
                    pos - since
                ));
            }
            last_pos.insert(tenant.clone(), pos);
            trace.push((tenant, item));
        }
        if !q.is_empty() || model.values().any(|l| !l.is_empty()) {
            return Err("drain left items behind".into());
        }
        Ok(trace)
    }

    run_prop("drr-tenant-churn", PropConfig::default(), |rng, size| {
        let seed = rng.next_u64();
        let steps = 100 + 50 * size;
        let first = match run_churn(seed, steps) {
            Ok(t) => t,
            Err(e) => return CaseResult::Fail(e),
        };
        let second = match run_churn(seed, steps) {
            Ok(t) => t,
            Err(e) => return CaseResult::Fail(e),
        };
        CaseResult::check(first == second, || {
            format!("same seed {seed:#x} produced different pop traces")
        })
    });
}
