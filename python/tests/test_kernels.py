"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal of the compile path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

# The artifacts are f32, but the kernels are dtype-generic; enable x64 so
# the float64 sweep exercises a genuinely different dtype.
jax.config.update("jax_enable_x64", True)

from compile.kernels import group_prox, matvec, ref, soft_threshold

SETTINGS = dict(max_examples=25, deadline=None)


def rng_arrays(seed, *shapes, dtype=np.float32):
    r = np.random.default_rng(seed)
    return [r.standard_normal(s).astype(dtype) for s in shapes]


# ---------------------------------------------------------------- fused BR


@settings(**SETTINGS)
@given(
    n=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31),
    tau=st.floats(min_value=1e-3, max_value=1e3),
    c=st.floats(min_value=1e-3, max_value=10.0),
)
def test_best_response_matches_ref(n, seed, tau, c):
    x, g = rng_arrays(seed, n, n)
    d = np.abs(rng_arrays(seed + 1, n)[0]) + 0.1
    xhat, e = soft_threshold.best_response(x, g, d, tau, c)
    xhat_ref, e_ref = ref.best_response(x, g, d, tau, c)
    np.testing.assert_allclose(xhat, xhat_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e, e_ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_best_response_dtypes(dtype):
    x, g = rng_arrays(3, 100, 100, dtype=dtype)
    d = np.abs(rng_arrays(4, 100, dtype=dtype)[0]) + 0.5
    xhat, e = soft_threshold.best_response(x, g, d, dtype(2.0), dtype(0.5))
    xr, er = ref.best_response(x, g, d, 2.0, 0.5)
    np.testing.assert_allclose(xhat, xr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(e, er, rtol=1e-5, atol=1e-6)
    assert xhat.dtype == dtype


def test_best_response_prox_property():
    """xhat minimizes the scalar surrogate: perturbations don't improve."""
    x, g = rng_arrays(5, 50, 50)
    d = np.abs(rng_arrays(6, 50)[0]) + 0.2
    tau, c = 1.3, 0.7
    xhat, _ = soft_threshold.best_response(x, g, d, tau, c)
    xhat = np.asarray(xhat)

    def surrogate(z):
        return g * (z - x) + 0.5 * (d + tau) * (z - x) ** 2 + c * np.abs(z)

    base = surrogate(xhat)
    for dz in (-1e-3, 1e-3):
        assert np.all(base <= surrogate(xhat + dz) + 1e-9)


def test_best_response_exact_zero_region():
    """Coordinates with |v| <= threshold land exactly at 0."""
    n = 64
    x = np.zeros(n, np.float32)
    g = np.full(n, 0.01, np.float32)  # tiny gradient, big threshold
    d = np.ones(n, np.float32)
    xhat, e = soft_threshold.best_response(x, g, d, 1.0, 5.0)
    assert np.all(np.asarray(xhat) == 0.0)
    assert np.all(np.asarray(e) == 0.0)


# ---------------------------------------------------------------- matvec


@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=400),
    n=st.integers(min_value=1, max_value=400),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matvec_matches_ref(m, n, seed):
    a, x = rng_arrays(seed, (m, n), n)
    y = matvec.matvec(a, x)
    np.testing.assert_allclose(y, ref.matvec(a, x), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=400),
    n=st.integers(min_value=1, max_value=400),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_rmatvec_matches_ref(m, n, seed):
    a, r = rng_arrays(seed, (m, n), m)
    g = matvec.rmatvec(a, r)
    np.testing.assert_allclose(g, ref.rmatvec(a, r), rtol=1e-4, atol=1e-4)


def test_matvec_non_divisible_tiles():
    """Shapes that don't divide the tile sizes are padded correctly."""
    a, x = rng_arrays(9, (131, 257), 257)
    np.testing.assert_allclose(
        matvec.matvec(a, x), ref.matvec(a, x), rtol=1e-4, atol=1e-4
    )
    r = rng_arrays(10, 131)[0]
    np.testing.assert_allclose(
        matvec.rmatvec(a, r), ref.rmatvec(a, r), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------- group prox


@settings(**SETTINGS)
@given(
    nb=st.integers(min_value=1, max_value=300),
    block=st.sampled_from([1, 2, 4, 8]),
    t=st.floats(min_value=1e-3, max_value=10.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_group_prox_matches_ref(nb, block, t, seed):
    v = rng_arrays(seed, nb * block)[0]
    out = group_prox.group_soft_threshold(v, t, block_size=block)
    out_ref = ref.group_soft_threshold(v, t, block)
    np.testing.assert_allclose(out, out_ref, rtol=1e-5, atol=1e-6)


def test_group_prox_kills_small_blocks():
    v = np.asarray([0.3, 0.4, 30.0, 40.0], np.float32)  # norms 0.5, 50
    out = np.asarray(group_prox.group_soft_threshold(v, 1.0, block_size=2))
    assert np.all(out[:2] == 0.0)
    np.testing.assert_allclose(np.linalg.norm(out[2:]), 49.0, rtol=1e-5)


def test_group_prox_block1_equals_soft_threshold():
    v = rng_arrays(11, 200)[0]
    out = group_prox.group_soft_threshold(v, 0.3, block_size=1)
    np.testing.assert_allclose(
        out, np.asarray(ref.soft_threshold(v, 0.3)), rtol=1e-5, atol=1e-6
    )
