"""L2 correctness: the model graphs vs the composed pure-jnp reference,
plus semantic checks (descent, selection, objective identity)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def instance(seed, m=40, n=120):
    r = np.random.default_rng(seed)
    a = r.standard_normal((m, n)).astype(np.float32)
    x_true = np.zeros(n, np.float32)
    idx = r.choice(n, n // 10, replace=False)
    x_true[idx] = r.standard_normal(len(idx)).astype(np.float32)
    b = (a @ x_true + 0.1 * r.standard_normal(m)).astype(np.float32)
    x = r.standard_normal(n).astype(np.float32) * 0.1
    d = (2.0 * (a * a).sum(axis=0)).astype(np.float32)
    return a, b, x, d


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_fpa_step_matches_ref(seed):
    a, b, x, d = instance(seed)
    args = (a, b, x, d, np.float32(3.0), np.float32(0.9), np.float32(0.5), np.float32(1.0))
    x1, v1, m1 = model.fpa_lasso_step(*args)
    x2, v2, m2 = ref.fpa_lasso_step(*args)
    np.testing.assert_allclose(x1, x2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(v1, v2, rtol=1e-4)
    np.testing.assert_allclose(m1, m2, rtol=1e-4)


def test_objective_identity():
    a, b, x, _ = instance(7)
    (v,) = model.objective(a, b, x, np.float32(0.5))
    r = a @ x - b
    expect = (r * r).sum() + 0.5 * np.abs(x).sum()
    np.testing.assert_allclose(v, expect, rtol=1e-5)


def test_fpa_iterations_descend():
    """Iterating the step decreases V.

    With a fixed tau at the majorizer scale (max d) the Jacobi map
    descends without needing the host-side tau adaptation.
    """
    a, b, x, d = instance(13)
    tau = np.float32(d.max())
    c = np.float32(1.0)
    v_prev = float(model.objective(a, b, x, c)[0])
    for _ in range(30):
        x, v_at_x, _ = model.fpa_lasso_step(
            a, b, x, d, tau, np.float32(0.9), np.float32(0.5), c
        )
    v_final = float(model.objective(a, b, np.asarray(x), c)[0])
    assert v_final < v_prev, f"{v_final} !< {v_prev}"


def test_fpa_step_fixed_point():
    """Iterating the map with a majorizer-scale tau drives max_E down
    (approach to a fixed point = stationary point, Prop. 3(b))."""
    a, b, x, d = instance(17)
    tau = np.float32(d.max())
    c = np.float32(1.0)
    _, _, m0 = model.fpa_lasso_step(a, b, x, d, tau, np.float32(0.9), np.float32(0.5), c)
    z = x
    for _ in range(300):
        z, _, m = model.fpa_lasso_step(a, b, z, d, tau, np.float32(0.9), np.float32(0.5), c)
    assert float(m) < 0.05 * float(m0), f"max_E {float(m)} vs initial {float(m0)}"


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_fista_step_matches_ref(seed):
    a, b, y, _ = instance(seed)
    x_prev = y * 0.5
    inv_l = np.float32(1e-3)
    args = (a, b, y, x_prev, np.float32(1.0), inv_l, np.float32(1.0))
    x1, y1, t1 = model.fista_step(*args)
    x2, y2, t2 = ref.fista_step(*args)
    np.testing.assert_allclose(x1, x2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(t1, t2, rtol=1e-6)


def test_group_step_matches_scalar_when_block1():
    a, b, x, d = instance(19, m=30, n=80)
    args = (a, b, x, d, np.float32(2.0), np.float32(0.8), np.float32(0.5), np.float32(1.0))
    x_g, v_g, m_g = model.fpa_group_lasso_step(*args, block_size=1)
    x_s, v_s, m_s = model.fpa_lasso_step(*args)
    np.testing.assert_allclose(x_g, x_s, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(v_g, v_s, rtol=1e-4)
    np.testing.assert_allclose(m_g, m_s, rtol=1e-3, atol=1e-6)


def test_group_step_blocks_descend():
    a, b, x, d = instance(23, m=30, n=80)
    c = np.float32(1.0)
    tau = np.float32(5.0)
    # Block-constant curvature for blocks of 4.
    d4 = d.reshape(-1, 4).sum(axis=1)
    d = np.repeat(d4, 4).astype(np.float32)
    v0 = None
    z = x
    for _ in range(25):
        z, v, _ = model.fpa_group_lasso_step(
            a, b, z, d, tau, np.float32(0.9), np.float32(0.5), c, block_size=4
        )
        if v0 is None:
            v0 = float(v)
    assert float(v) < v0
