"""AOT lowering: jit the L2 graphs, lower to HLO TEXT, write artifacts.

HLO *text* (not `.serialize()`d protos) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md and
gen_hlo.py there.

Usage (from python/):

    python -m compile.aot --out-dir ../artifacts \
        --shapes 100x400,200x1000 [--medium]

Writes `<graph>.<m>x<n>.hlo.txt` per graph/shape plus `manifest.txt`
(the contract consumed by rust/src/runtime/registry.rs).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DTYPE = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def scalar():
    return jax.ShapeDtypeStruct((), DTYPE)


def lower_fpa_lasso_step(m, n):
    fn = jax.jit(model.fpa_lasso_step)
    return fn.lower(
        spec((m, n)), spec((m,)), spec((n,)), spec((n,)),
        scalar(), scalar(), scalar(), scalar(),
    )


def lower_objective(m, n):
    fn = jax.jit(model.objective)
    return fn.lower(spec((m, n)), spec((m,)), spec((n,)), scalar())


def lower_fista_step(m, n):
    fn = jax.jit(model.fista_step)
    return fn.lower(
        spec((m, n)), spec((m,)), spec((n,)), spec((n,)),
        scalar(), scalar(), scalar(),
    )


def lower_fpa_group_step(m, n, block_size):
    fn = jax.jit(functools.partial(model.fpa_group_lasso_step, block_size=block_size))
    return fn.lower(
        spec((m, n)), spec((m,)), spec((n,)), spec((n,)),
        scalar(), scalar(), scalar(), scalar(),
    )


GRAPHS = {
    "fpa_lasso_step": lower_fpa_lasso_step,
    "objective": lower_objective,
    "fista_step": lower_fista_step,
}


def build(out_dir, shapes, group_block=4):
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = ["# artifacts built by python/compile/aot.py"]
    for (m, n) in shapes:
        for graph, lower in GRAPHS.items():
            name = f"{graph}.{m}x{n}"
            fname = f"{name}.hlo.txt"
            text = to_hlo_text(lower(m, n))
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest_lines.append(f"{name} {fname} rows={m} cols={n} dtype=f32")
            print(f"wrote {fname} ({len(text)} chars)")
        # Group-lasso step only for shapes divisible by the block size.
        if n % group_block == 0:
            name = f"fpa_group{group_block}_step.{m}x{n}"
            fname = f"{name}.hlo.txt"
            text = to_hlo_text(lower_fpa_group_step(m, n, group_block))
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest_lines.append(f"{name} {fname} rows={m} cols={n} dtype=f32")
            print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines) - 1} artifacts")


def parse_shapes(s):
    shapes = []
    for part in s.split(","):
        m, n = part.strip().split("x")
        shapes.append((int(m), int(n)))
    return shapes


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default="100x400,200x1000",
        help="comma-separated MxN shape classes to AOT",
    )
    ap.add_argument(
        "--medium",
        action="store_true",
        help="also AOT the paper's medium panel shape (2000x10000; slow)",
    )
    args = ap.parse_args()
    shapes = parse_shapes(args.shapes)
    if args.medium:
        shapes.append((2000, 10000))
    build(args.out_dir, shapes)


if __name__ == "__main__":
    main()
