"""L2: the FPA iteration and companion graphs as jitted JAX functions.

Each function here is lowered once by `aot.py` to an HLO-text artifact
that the Rust coordinator loads via PJRT. The hot operations call the L1
Pallas kernels (interpret=True, so the lowering is plain HLO the CPU
client can run); the glue (selection, step, reductions) is jnp.

Python is BUILD-TIME ONLY: nothing in this package is imported at solve
time.
"""

import jax.numpy as jnp

from .kernels import matvec as mv
from .kernels import soft_threshold as st
from .kernels import group_prox as gp


def fpa_lasso_step(a, b, x, d, tau, gamma, rho, c):
    """One FPA iteration (Algorithm 1, Example #2, eq. (6) best-response).

    Steps fused in-graph:
      (S.2) residual + gradient (Pallas matvec kernels) and the fused
            soft-threshold best-response + error bound (Pallas kernel);
      (S.3) greedy rho-selection: update blocks with E_i >= rho * max E;
      (S.4) x_next = x + gamma * (xhat - x) on the selected set.

    Returns (x_next, V(x), max_E); V is at the *input* iterate (the Rust
    host compares consecutive values for the tau adaptation).
    """
    r = mv.matvec(a, x) - b
    f = jnp.sum(r * r)
    g = 2.0 * mv.rmatvec(a, r)
    xhat, e = st.best_response(x, g, d, tau, c)
    m = jnp.max(e)
    mask = e >= rho * m
    x_next = jnp.where(mask, x + gamma * (xhat - x), x)
    v = f + c * jnp.sum(jnp.abs(x))
    return x_next, v, m


def objective(a, b, x, c):
    """V(x) = ||Ax-b||^2 + c||x||_1 (Pallas matvec for the residual)."""
    r = mv.matvec(a, x) - b
    return (jnp.sum(r * r) + c * jnp.sum(jnp.abs(x)),)


def fista_step(a, b, y, x_prev, t, inv_l, c):
    """One FISTA iteration on the Lasso (parallel benchmark).

    Returns (x_next, y_next, t_next).
    """
    r = mv.matvec(a, y) - b
    g = 2.0 * mv.rmatvec(a, r)
    n = y.shape[0]
    ones = jnp.ones((n,), dtype=y.dtype)
    # Reuse the fused BR kernel with d = 0, tau = 1/inv_l: it computes
    # S_{c*inv_l}(y - inv_l * g) exactly.
    x_next, _ = st.best_response(y, g, 0.0 * ones, 1.0 / inv_l, c)
    t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
    y_next = x_next + ((t - 1.0) / t_next) * (x_next - x_prev)
    return x_next, y_next, t_next


def fpa_group_lasso_step(a, b, x, d, tau, gamma, rho, c, *, block_size):
    """FPA iteration for the group Lasso (block soft-threshold prox).

    Same structure as `fpa_lasso_step` but the prox is the Pallas group
    kernel and the error bound / selection operate per block.
    """
    n = x.shape[0]
    assert n % block_size == 0
    r = mv.matvec(a, x) - b
    f = jnp.sum(r * r)
    g = 2.0 * mv.rmatvec(a, r)
    denom = d + tau  # d is constant within each block by construction
    v = x - g / denom
    # Per-block threshold: c/denom is constant within a block; the group
    # kernel takes a scalar, so scale v by denom first:
    # prox_{c/denom * ||.||}(v) = (1/denom) * prox_{c * ||.||}(denom * v).
    xhat = gp.group_soft_threshold(v * denom, c, block_size=block_size) / denom
    e_coord = (xhat - x) ** 2
    e_blocks = jnp.sqrt(jnp.sum(e_coord.reshape(-1, block_size), axis=1))
    m = jnp.max(e_blocks)
    mask_blocks = e_blocks >= rho * m
    mask = jnp.repeat(mask_blocks, block_size)
    x_next = jnp.where(mask, x + gamma * (xhat - x), x)
    v_obj = f + c * jnp.sum(
        jnp.sqrt(jnp.sum((x.reshape(-1, block_size)) ** 2, axis=1))
    )
    return x_next, v_obj, m
