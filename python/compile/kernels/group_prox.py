"""L1 Pallas kernel: block (group) soft-threshold — the group-Lasso
best-response prox (paper §2, third bullet).

Each grid instance handles a tile of whole blocks: reshapes its
(TILE_BLOCKS * block_size,) slab to (TILE_BLOCKS, block_size), computes
per-block norms on the VPU, and rescales. Block boundaries never cross
tile boundaries by construction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCKS = 128


def _group_kernel(block_size, v_ref, t_ref, out_ref):
    v = v_ref[...].reshape(-1, block_size)
    t = t_ref[0]
    norms = jnp.sqrt(jnp.sum(v * v, axis=1, keepdims=True))
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-30))
    out_ref[...] = (v * scale).reshape(-1)


@functools.partial(jax.jit, static_argnames=("block_size", "tile_blocks"))
def group_soft_threshold(v, t, *, block_size, tile_blocks=TILE_BLOCKS):
    """Per-block prox of t*||.||_2 over contiguous equal-size blocks."""
    n = v.shape[0]
    assert n % block_size == 0, "n must be divisible by block_size"
    nb = n // block_size
    nb_pad = (nb + tile_blocks - 1) // tile_blocks * tile_blocks
    vp = jnp.pad(v, (0, (nb_pad - nb) * block_size))
    t_arr = jnp.asarray([t], dtype=v.dtype)
    tile = tile_blocks * block_size
    grid = (nb_pad // tile_blocks,)
    kernel = functools.partial(_group_kernel, block_size)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb_pad * block_size,), v.dtype),
        interpret=True,
    )(vp, t_arr)
    return out[:n]
