"""Pallas kernels (L1) and their pure-jnp oracles."""

from . import group_prox, matvec, ref, soft_threshold

__all__ = ["group_prox", "matvec", "ref", "soft_threshold"]
