"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal of the compile path: pytest checks
each Pallas kernel (interpret=True) against these references over a sweep
of shapes and dtypes (hypothesis), and the L2 model graph against the
composed reference step. The Rust integration tests then check the
AOT-compiled artifacts against the native Rust implementation, closing
the three-layer loop.
"""

import jax.numpy as jnp


def soft_threshold(v, t):
    """S_t(v) = sign(v) * max(|v| - t, 0) (prox of t*|.|, paper eq. (6))."""
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0)


def matvec(a, x):
    """y = A @ x."""
    return a @ x


def rmatvec(a, r):
    """g = A.T @ r."""
    return a.T @ r


def best_response(x, g, d, tau, c):
    """Fused Lasso best-response (paper eq. (6)) + error bound.

    xhat_j = S_{c/(d_j+tau)}(x_j - g_j/(d_j+tau)),  e_j = |xhat_j - x_j|.
    """
    denom = d + tau
    v = x - g / denom
    xhat = soft_threshold(v, c / denom)
    return xhat, jnp.abs(xhat - x)


def group_soft_threshold(v, t, block_size):
    """Block soft-threshold over contiguous blocks (group Lasso prox).

    v has length divisible by block_size; threshold t applies per block:
    out_blk = max(0, 1 - t/||v_blk||) * v_blk.
    """
    vb = v.reshape(-1, block_size)
    norms = jnp.linalg.norm(vb, axis=1, keepdims=True)
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-30))
    return (vb * scale).reshape(-1)


def objective(a, b, x, c):
    """V(x) = ||Ax - b||^2 + c*||x||_1."""
    r = a @ x - b
    return jnp.sum(r * r) + c * jnp.sum(jnp.abs(x))


def fpa_lasso_step(a, b, x, d, tau, gamma, rho, c):
    """One full FPA iteration (Algorithm 1, Example #2 with eq. (6)).

    Returns (x_next, V(x), max_E). Selection (S.3) is the greedy rho-rule
    fused in-graph; the step (S.4) uses gamma.
    """
    r = a @ x - b
    f = jnp.sum(r * r)
    g = 2.0 * (a.T @ r)
    xhat, e = best_response(x, g, d, tau, c)
    m = jnp.max(e)
    mask = e >= rho * m
    x_next = jnp.where(mask, x + gamma * (xhat - x), x)
    v = f + c * jnp.sum(jnp.abs(x))
    return x_next, v, m


def fista_step(a, b, y, x_prev, t, inv_l, c):
    """One FISTA iteration on the Lasso.

    Returns (x_next, y_next, t_next).
    """
    r = a @ y - b
    g = 2.0 * (a.T @ r)
    x_next = soft_threshold(y - inv_l * g, inv_l * c)
    t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
    y_next = x_next + ((t - 1.0) / t_next) * (x_next - x_prev)
    return x_next, y_next, t_next
