"""L1 Pallas kernels: tiled mat-vec products — the FLOP hot spot.

The per-iteration cost of every method in the paper is dominated by the
two BLAS-2 passes `r = Ax - b` and `g = 2 A^T r`. On TPU these are
memory-bound (one streaming read of A each); the tiling below expresses
the HBM->VMEM schedule:

* `matvec`:  grid over row tiles; each instance holds an (TM, n) slab of
  A and the full x in VMEM and emits a (TM,) slice of y.
* `rmatvec`: grid over column tiles; each instance holds an (m, TN) slab
  and the full r, emitting a (TN,) slice of g.

Slab sizes are chosen so a (TM, n) f32 slab stays in the low-MiB range
for the paper's shapes (TM=128, n=10k -> 5 MiB), inside the ~16 MiB VMEM
budget with double-buffering headroom. interpret=True for CPU-PJRT
execution (see soft_threshold.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128


def _matvec_kernel(a_ref, x_ref, y_ref):
    y_ref[...] = a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def matvec(a, x, *, tile_m=TILE_M):
    """y = A @ x via row-tiled Pallas kernel."""
    m, n = a.shape
    m_pad = (m + tile_m - 1) // tile_m * tile_m
    ap = jnp.pad(a, ((0, m_pad - m), (0, 0)))
    grid = (m_pad // tile_m,)
    y = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m_pad,), a.dtype),
        interpret=True,
    )(ap, x)
    return y[:m]


def _rmatvec_kernel(a_ref, r_ref, g_ref):
    g_ref[...] = r_ref[...] @ a_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_n",))
def rmatvec(a, r, *, tile_n=TILE_N):
    """g = A.T @ r via column-tiled Pallas kernel."""
    m, n = a.shape
    n_pad = (n + tile_n - 1) // tile_n * tile_n
    ap = jnp.pad(a, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // tile_n,)
    g = pl.pallas_call(
        _rmatvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile_n), lambda i: (0, i)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), a.dtype),
        interpret=True,
    )(ap, r)
    return g[:n]
