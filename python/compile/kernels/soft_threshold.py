"""L1 Pallas kernel: fused FPA best-response + error bound.

The paper's hot inner operation (S.2): for every coordinate,

    denom_j = d_j + tau
    xhat_j  = S_{c/denom_j}(x_j - g_j/denom_j)
    e_j     = |xhat_j - x_j|

One fused pass over four n-vectors — on TPU this is a VPU-bound kernel
tiled so each block (x, g, d, xhat, e tiles) fits VMEM with room for
double-buffering; the scalars (tau, c) ride along as (1,)-shaped operands
(SMEM on real hardware).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; lowering in interpret mode emits plain HLO so the artifact
runs on the Rust CPU client (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size for the 1-D sweep. 1024 f32 lanes * 5 buffers = 20 KiB of
# VMEM per instance — far under the ~16 MiB budget, leaving headroom for
# double-buffering the HBM->VMEM pipeline on real hardware.
TILE = 1024


def _br_kernel(x_ref, g_ref, d_ref, tau_ref, c_ref, xhat_ref, e_ref):
    x = x_ref[...]
    g = g_ref[...]
    d = d_ref[...]
    tau = tau_ref[0]
    c = c_ref[0]
    denom = d + tau
    v = x - g / denom
    t = c / denom
    xhat = jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0)
    xhat_ref[...] = xhat
    e_ref[...] = jnp.abs(xhat - x)


@functools.partial(jax.jit, static_argnames=("tile",))
def best_response(x, g, d, tau, c, *, tile=TILE):
    """Fused best-response over n coordinates; returns (xhat, e).

    Pads n up to a multiple of `tile` (the pad coordinates produce
    garbage that is sliced away; d=1 padding avoids div-by-zero).
    """
    n = x.shape[0]
    n_pad = (n + tile - 1) // tile * tile
    pad = n_pad - n
    xp = jnp.pad(x, (0, pad))
    gp = jnp.pad(g, (0, pad))
    dp = jnp.pad(d, (0, pad), constant_values=1.0)
    tau_arr = jnp.asarray([tau], dtype=x.dtype)
    c_arr = jnp.asarray([c], dtype=x.dtype)

    grid = (n_pad // tile,)
    vec_spec = pl.BlockSpec((tile,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    xhat, e = pl.pallas_call(
        _br_kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec, scalar_spec, scalar_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), x.dtype),
            jax.ShapeDtypeStruct((n_pad,), x.dtype),
        ],
        interpret=True,
    )(xp, gp, dp, tau_arr, c_arr)
    return xhat[:n], e[:n]
