//! Group Lasso (paper §2, third bullet): `min ‖Ax−b‖² + c·Σᵢ‖xᵢ‖₂`.
//!
//! Demonstrates the framework's block flexibility (`nᵢ > 1`) through the
//! unified session API: the same `group_lasso` problem spec runs against
//! FPA (block soft-threshold best-response, greedy ρ-selection over
//! whole blocks), FISTA and block Gauss–Seidel, by registry name alone.
//!
//! Run: `cargo run --release --example group_lasso`

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Session};
use flexa::linalg::ops;
use flexa::problems::BlockLayout;

fn main() -> anyhow::Result<()> {
    let (m, n, block) = (300, 1200, 4);
    let spec = ProblemSpec::group_lasso(m, n, block)
        .with_sparsity(0.1)
        .with_c(1.0)
        .with_seed(11);
    let layout = BlockLayout::uniform(n, block);
    println!("group lasso: A {m}x{n}, {} blocks of {block} variables", layout.num_blocks());

    let opts = SolveOptions::default().with_max_iters(4000).with_target(0.0);
    let mut results = Vec::new();
    for algo in ["fpa", "fista", "gauss-seidel"] {
        let run = Session::problem(spec.clone())
            .solver_named(algo)?
            .options(opts.clone())
            .run()?;
        results.push((algo, run));
    }

    // No planted V* for the group problem: use the best found across all
    // methods as the reference and report gaps.
    let v_best = results
        .iter()
        .map(|(_, r)| r.objective)
        .fold(f64::INFINITY, f64::min);
    println!("best objective found: {v_best:.6}");
    for (name, r) in &results {
        let gap = (r.objective - v_best) / v_best.abs().max(1.0);
        // Count active (non-zero) groups of the solution.
        let active = (0..layout.num_blocks())
            .filter(|&i| ops::nrm2(&r.x[layout.range(i)]) > 1e-6)
            .count();
        println!(
            "  {name:<14} V = {:.6}  gap = {gap:.2e}  active groups = {active}  iters = {}  t = {:.2}s",
            r.objective,
            r.iterations,
            r.report.trace.last().map(|l| l.time_s).unwrap_or(0.0)
        );
    }
    Ok(())
}
