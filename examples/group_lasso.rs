//! Group Lasso (paper §2, third bullet): `min ‖Ax−b‖² + c·Σᵢ‖xᵢ‖₂`.
//!
//! Demonstrates the framework's block flexibility (`nᵢ > 1`): the same
//! Algorithm 1 with the block soft-threshold best-response recovers
//! group-sparse structure, and the greedy ρ-selection operates on whole
//! blocks. Compares FPA against FISTA and block Gauss-Seidel.
//!
//! Run: `cargo run --release --example group_lasso`

use flexa::algos::fista::Fista;
use flexa::algos::fpa::Fpa;
use flexa::algos::gauss_seidel::GaussSeidel;
use flexa::algos::{SolveOptions, Solver};
use flexa::datagen::NesterovLasso;
use flexa::linalg::ops;
use flexa::problems::group_lasso::GroupLasso;
use flexa::problems::CompositeProblem;

fn main() {
    let (m, n, block) = (300, 1200, 4);
    // Plant a group-sparse signal: reuse the Nesterov instance for A and
    // b (its scalar-sparse x* also has group structure at block level).
    let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(11).generate();
    let problem = GroupLasso::new(inst.a, inst.b, 1.0, block);
    println!(
        "group lasso: A {}x{}, {} blocks of {} variables",
        m,
        n,
        problem.layout().num_blocks(),
        block
    );

    let opts = SolveOptions::default().with_max_iters(4000).with_target(0.0);
    let mut results = Vec::new();
    results.push(("fpa", Fpa::paper_defaults(&problem).solve(&problem, &opts)));
    results.push(("fista", Fista::default().solve(&problem, &opts)));
    results.push(("block-gs", GaussSeidel::default().solve(&problem, &opts)));

    // No planted V* for the group problem: use the best found across all
    // methods as the reference and report gaps.
    let v_best = results
        .iter()
        .map(|(_, r)| r.objective)
        .fold(f64::INFINITY, f64::min);
    println!("best objective found: {v_best:.6}");
    for (name, r) in &results {
        let gap = (r.objective - v_best) / v_best.abs().max(1.0);
        // Count active (non-zero) groups of the solution.
        let active = (0..problem.layout().num_blocks())
            .filter(|&i| ops::nrm2(&r.x[problem.layout().range(i)]) > 1e-6)
            .count();
        println!(
            "  {name:<10} V = {:.6}  gap = {gap:.2e}  active groups = {active}  iters = {}  t = {:.2}s",
            r.objective,
            r.iterations,
            r.trace.last().map(|l| l.time_s).unwrap_or(0.0)
        );
    }
}
