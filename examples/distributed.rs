//! Distributed coordinator demo: the threaded leader/worker FPA
//! (mirroring the paper's MPI layout) with the bulk-synchronous cost
//! model projecting single-core measurements onto 1–32 processes.
//!
//! Shows (i) exact parity between the serial and the threaded solver,
//! and (ii) the simulated speedup curve for the paper's process counts.
//!
//! Run: `cargo run --release --example distributed`

use flexa::algos::fpa::Fpa;
use flexa::algos::{SolveOptions, Solver};
use flexa::coordinator::{CostModel, ParallelFpa};
use flexa::datagen::NesterovLasso;
use flexa::linalg::ops;
use flexa::problems::lasso::Lasso;

fn main() {
    let (m, n) = (500, 2500);
    let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(31).generate();
    let problem = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
    println!("instance: {m}x{n}, 10% nnz\n");

    // 1. Parity: threaded coordinator == serial solver, iteration for
    //    iteration (only float reduction order differs).
    let opts = SolveOptions::default().with_max_iters(300).with_target(1e-5);
    let serial = Fpa::paper_defaults(&problem).solve(&problem, &opts);
    let threaded = ParallelFpa::paper_defaults(4).solve(&problem, &opts);
    println!(
        "parity: serial {} iters vs threaded {} iters, ‖x_serial − x_threaded‖ = {:.2e}\n",
        serial.iterations,
        threaded.iterations,
        ops::dist2(&serial.x, &threaded.x)
    );

    // 2. Simulated scaling: per-iteration times under the BSP cost model
    //    for the paper's process counts (single-core measurements,
    //    max-over-workers + allreduce estimate).
    println!("simulated scaling (time to rel err 1e-4):");
    println!("{:>8} {:>14} {:>14} {:>10}", "procs", "measured(s)", "simulated(s)", "speedup");
    let mut t1 = None;
    for procs in [1usize, 2, 4, 8, 16, 32] {
        let opts = SolveOptions::default()
            .with_max_iters(2000)
            .with_target(1e-4)
            .with_cost_model(CostModel::mpi_node(procs));
        let report = ParallelFpa::paper_defaults(procs.min(8)).solve(&problem, &opts);
        let measured = report.trace.time_to_rel_err(1e-4, false);
        let simulated = report.trace.time_to_rel_err(1e-4, true);
        if let (Some(ms), Some(ss)) = (measured, simulated) {
            let t1v = *t1.get_or_insert(ss);
            println!("{procs:>8} {ms:>14.3} {ss:>14.3} {:>9.1}x", t1v / ss);
        } else {
            println!("{procs:>8} {:>14} {:>14} {:>10}", "-", "-", "-");
        }
    }
    println!("\n(threads timeshare one core here; the simulated clock is the");
    println!(" max-over-workers BSP estimate the paper's 16/32-process curves use)");
}
