//! Distributed coordinator demo: the threaded leader/worker FPA
//! (mirroring the paper's MPI layout) with the bulk-synchronous cost
//! model projecting single-core measurements onto 1–32 processes —
//! driven entirely through the session API (`fpa` vs `pfpa` registry
//! solvers).
//!
//! Shows (i) exact parity between the serial and the threaded solver,
//! and (ii) the simulated speedup curve for the paper's process counts.
//!
//! Run: `cargo run --release --example distributed`

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Session, SolverSpec};
use flexa::coordinator::CostModel;
use flexa::linalg::ops;

fn main() -> anyhow::Result<()> {
    let (m, n) = (500, 2500);
    let spec = ProblemSpec::lasso(m, n).with_sparsity(0.1).with_seed(31);
    println!("instance: {m}x{n}, 10% nnz\n");

    // 1. Parity: threaded coordinator == serial solver, iteration for
    //    iteration (only float reduction order differs).
    let opts = SolveOptions::default().with_max_iters(300).with_target(1e-5);
    let serial = Session::problem(spec.clone())
        .solver_named("fpa")?
        .options(opts.clone())
        .run()?;
    let threaded = Session::problem(spec.clone())
        .solver(SolverSpec::new("pfpa").with_param("workers", 4.0))
        .options(opts)
        .run()?;
    println!(
        "parity: serial {} iters vs threaded {} iters, ‖x_serial − x_threaded‖ = {:.2e}\n",
        serial.iterations,
        threaded.iterations,
        ops::dist2(&serial.x, &threaded.x)
    );

    // 2. Simulated scaling: per-iteration times under the BSP cost model
    //    for the paper's process counts (single-core measurements,
    //    max-over-workers + allreduce estimate).
    println!("simulated scaling (time to rel err 1e-4):");
    println!("{:>8} {:>14} {:>14} {:>10}", "procs", "measured(s)", "simulated(s)", "speedup");
    let mut t1 = None;
    for procs in [1usize, 2, 4, 8, 16, 32] {
        let opts = SolveOptions::default()
            .with_max_iters(2000)
            .with_target(1e-4)
            .with_cost_model(CostModel::mpi_node(procs));
        let run = Session::problem(spec.clone())
            .solver(SolverSpec::new("pfpa").with_param("workers", procs.min(8) as f64))
            .options(opts)
            .run()?;
        let measured = run.report.trace.time_to_rel_err(1e-4, false);
        let simulated = run.report.trace.time_to_rel_err(1e-4, true);
        if let (Some(ms), Some(ss)) = (measured, simulated) {
            let t1v = *t1.get_or_insert(ss);
            println!("{procs:>8} {ms:>14.3} {ss:>14.3} {:>9.1}x", t1v / ss);
        } else {
            println!("{procs:>8} {:>14} {:>14} {:>10}", "-", "-", "-");
        }
    }
    println!("\n(threads timeshare one core here; the simulated clock is the");
    println!(" max-over-workers BSP estimate the paper's 16/32-process curves use)");
    Ok(())
}
