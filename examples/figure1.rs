//! End-to-end driver: regenerate the paper's Fig. 1 (relative error vs
//! time for FPA vs FISTA / GRock / Gauss-Seidel / ADMM) on a real
//! workload, exercising the full stack: problem/solver specs → the
//! `flexa::api` session registry → all six solvers → simulated-parallel
//! cost model → CSV + ASCII rendering.
//!
//! Run (scaled panels, a few minutes):
//!   cargo run --release --example figure1
//! Options:
//!   cargo run --release --example figure1 -- --panel d --scale 0.05
//!   cargo run --release --example figure1 -- --full      # paper sizes
//!
//! The per-panel CSV series land in results/; EXPERIMENTS.md records the
//! paper-vs-measured comparison for the checked-in run.

use flexa::bench::fig1::{paper_algos, run_panel, PanelSpec};
use flexa::cli::Command;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("figure1", "regenerate the paper's Fig. 1 panels")
        .opt("panel", Some("all"), "a | b | c | d | all")
        .opt("scale", Some("0.2"), "problem-size scale (1.0 = paper size)")
        .opt("realizations", Some("1"), "instances averaged per panel")
        .opt("budget", Some("60"), "per-solver wall-clock budget (s)")
        .opt("out", Some("results"), "output directory")
        .flag("full", "run the paper-size panels (hours on one core)");
    let p = cmd.parse(&args)?;

    let panels: Vec<char> = match p.str("panel")? {
        "all" => vec!['a', 'b', 'c', 'd'],
        s => vec![s.chars().next().unwrap()],
    };
    let scale = if p.flag("full") { 1.0 } else { p.f64("scale")? };
    let out = Path::new(p.str("out")?).to_path_buf();

    for panel in panels {
        // Panel d is 10x the work of a-c; shrink it further by default so
        // the default run stays laptop-sized.
        let eff_scale = if panel == 'd' && !p.flag("full") { scale * 0.25 } else { scale };
        let spec = PanelSpec::paper(panel)?
            .scaled(eff_scale)
            .with_realizations(p.usize("realizations")?)
            .with_budget(p.f64("budget")?);
        let algos = paper_algos(spec.procs);
        println!(
            "\n=== panel ({panel}): {}x{}, {:.0}% nnz, {} simulated procs ===",
            spec.rows,
            spec.cols,
            spec.sparsity * 100.0,
            spec.procs
        );
        let result = run_panel(&spec, &algos, Some(&out))?;
        println!("{}", result.render(true));
        println!("{}", result.summary_table(true));
    }
    println!("CSV series in {}", out.display());
    Ok(())
}
