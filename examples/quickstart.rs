//! Quickstart: generate a planted Lasso instance, solve it with FPA
//! (the paper's Algorithm 1, Example #2 configuration), and inspect the
//! convergence trace.
//!
//! Run: `cargo run --release --example quickstart`

use flexa::algos::{fpa::Fpa, SolveOptions, Solver};
use flexa::datagen::NesterovLasso;
use flexa::linalg::ops;
use flexa::problems::lasso::Lasso;

fn main() {
    // A 500 x 2 500 Lasso instance with 10% non-zeros in the planted
    // solution (Nesterov's generator: x* and V* are known exactly).
    let gen = NesterovLasso::new(500, 2500, 0.10, 1.0).seed(7);
    let inst = gen.generate();
    println!(
        "instance: A is {}x{}, ‖x*‖₀ = {}, V* = {:.6}",
        500,
        2500,
        ops::nnz(&inst.x_star, 0.0),
        inst.v_star
    );

    let x_star = inst.x_star.clone();
    let problem = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);

    // FPA with the paper's parameters: exact best-response (6),
    // greedy selection with rho = 0.5, gamma rule (4), adaptive tau.
    let mut solver = Fpa::paper_defaults(&problem);
    let opts = SolveOptions::default().with_max_iters(5000).with_target(1e-6);
    let report = solver.solve(&problem, &opts);

    println!(
        "solved: {} iterations, V = {:.6}, rel err = {:.2e}, converged = {}",
        report.iterations,
        report.objective,
        report.trace.best_rel_err(),
        report.converged
    );
    println!(
        "support recovered: {} / {} coordinates match x*",
        report
            .x
            .iter()
            .zip(&x_star)
            .filter(|(a, b)| (a.abs() > 1e-6) == (b.abs() > 1e-6))
            .count(),
        x_star.len()
    );

    // Milestones from the trace (the data behind the paper's Fig. 1).
    for target in [1e-2, 1e-4, 1e-6] {
        match report.trace.time_to_rel_err(target, false) {
            Some(t) => println!("  rel err {target:.0e} reached at {t:.3}s"),
            None => println!("  rel err {target:.0e} not reached"),
        }
    }
}
