//! Quickstart: describe a planted Lasso instance and the paper's
//! Algorithm 1 as specs, run them through the unified `flexa::api`
//! session, and watch the solve stream live iteration events.
//!
//! Run: `cargo run --release --example quickstart`

use flexa::algos::SolveOptions;
use flexa::api::{CollectObserver, ProblemSpec, Session, SolverSpec};
use flexa::datagen::NesterovLasso;
use flexa::linalg::ops;

fn main() -> anyhow::Result<()> {
    // A 500 x 2 500 Lasso instance with 10% non-zeros in the planted
    // solution (Nesterov's generator: x* and V* are known exactly).
    // The spec is a complete, serializable description of the instance.
    let spec = ProblemSpec::lasso(500, 2500).with_sparsity(0.10).with_c(1.0).with_seed(7);
    println!("problem spec: {spec}");

    // FPA with the paper's parameters: exact best-response (6), greedy
    // selection with rho = 0.5, gamma rule (4), adaptive tau. Any other
    // registry name works here: "fista", "grock-16", "fpa-rho-0.9", ...
    let solver = SolverSpec::parse("fpa")?;

    // The observer streams (iter, gamma, tau, |S^k|, objective) per
    // iteration — a dashboard would subscribe exactly like this.
    let observer = CollectObserver::new();
    let run = Session::problem(spec)
        .solver(solver)
        .options(SolveOptions::default().with_max_iters(5000).with_target(1e-6))
        .observer(observer.clone())
        .run()?;

    println!(
        "solved: {} iterations, V = {:.6}, rel err = {:.2e}, converged = {}",
        run.iterations,
        run.objective,
        run.report.trace.best_rel_err(),
        run.converged
    );
    let first = observer.events().first().copied();
    println!(
        "streamed {} events; first: gamma = {:.3}, |S| = {} of {} blocks",
        observer.len(),
        first.map(|e| e.gamma).unwrap_or(f64::NAN),
        first.map(|e| e.updated_blocks).unwrap_or(0),
        observer.dim(),
    );

    // Spec-driven generation is deterministic, so the planted solution is
    // reproducible outside the session for evaluation.
    let inst = NesterovLasso::new(500, 2500, 0.10, 1.0).seed(7).generate();
    println!(
        "support recovered: {} / {} coordinates match x*",
        run.x
            .iter()
            .zip(&inst.x_star)
            .filter(|(a, b)| (a.abs() > 1e-6) == (b.abs() > 1e-6))
            .count(),
        inst.x_star.len()
    );

    // Milestones from the trace (the data behind the paper's Fig. 1).
    for target in [1e-2, 1e-4, 1e-6] {
        match run.report.trace.time_to_rel_err(target, false) {
            Some(t) => println!("  rel err {target:.0e} reached at {t:.3}s"),
            None => println!("  rel err {target:.0e} not reached"),
        }
    }
    println!("nnz of solution: {}", ops::nnz(&run.x, 1e-6));
    Ok(())
}
