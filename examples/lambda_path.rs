//! λ-path: solve one Lasso design matrix under a decreasing sequence of
//! regularization weights, warm-starting every step from the previous
//! solution through the `flexa::serve` scheduler's cache.
//!
//! The cache keys on a fingerprint of the problem *data* (A, b, layout)
//! that deliberately excludes λ, so all eight steps share one entry:
//! step i starts from step i−1's solution and its adapted τ. With one
//! worker the steps run in submission order, which is what makes the
//! previous-λ solution the warm start.
//!
//! Run: `cargo run --release --example lambda_path`

use flexa::algos::{SolveOptions, Solver};
use flexa::api::{ProblemHandle, SolverSpec};
use flexa::datagen::NesterovLasso;
use flexa::problems::lasso::Lasso;
use flexa::serve::{CustomProblemFn, JobResult, JobSpec, Scheduler, ServeConfig};
use std::sync::Arc;

fn iters(r: &JobResult) -> usize {
    r.report.as_ref().map(|rep| rep.iterations).unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    // One shared design matrix; the sweep only changes λ.
    let (rows, cols) = (100, 400);
    let inst = NesterovLasso::new(rows, cols, 0.1, 1.0).seed(42).generate();
    let a = Arc::new(inst.a);
    let b = Arc::new(inst.b);
    let lambdas: Vec<f64> = (0..8).map(|i| 4.0 * 0.7f64.powi(i)).collect();
    println!("lambda path on a {rows}x{cols} Lasso, lambda {:.2} -> {:.2}", lambdas[0], lambdas[7]);

    // Reference optima V*(λ) via heavy Gauss-Seidel sweeps, so each step
    // has a meaningful relative-error target.
    let v_refs: Vec<f64> = lambdas
        .iter()
        .map(|&lam| {
            let p = Lasso::new((*a).clone(), (*b).clone(), lam);
            flexa::algos::gauss_seidel::GaussSeidel::default()
                .solve(
                    &p,
                    &SolveOptions::default()
                        .with_max_iters(400)
                        .with_target(0.0)
                        .with_record_every(400),
                )
                .objective
        })
        .collect();

    let opts = SolveOptions::default().with_max_iters(20_000).with_target(1e-4);
    let run_path = |warm: bool| -> Vec<usize> {
        let scheduler = Scheduler::start(ServeConfig::default().with_workers(1));
        for (i, &lam) in lambdas.iter().enumerate() {
            let (a, b, v_ref) = (Arc::clone(&a), Arc::clone(&b), v_refs[i]);
            let build: CustomProblemFn = Arc::new(move || {
                Ok(ProblemHandle::least_squares(
                    Lasso::new((*a).clone(), (*b).clone(), lam).with_opt_value(v_ref),
                ))
            });
            scheduler.submit(
                JobSpec::custom(&format!("lambda-{lam:.3}"), build, SolverSpec::parse("fpa").unwrap())
                    .with_opts(opts.clone())
                    .with_warm_start(warm),
            );
        }
        scheduler.join().iter().map(iters).collect()
    };

    let cold = run_path(false);
    let warm = run_path(true);

    println!("\n{:>10} {:>12} {:>12} {:>10}", "lambda", "cold iters", "warm iters", "ratio");
    for i in 0..lambdas.len() {
        println!(
            "{:>10.3} {:>12} {:>12} {:>10.3}{}",
            lambdas[i],
            cold[i],
            warm[i],
            warm[i] as f64 / cold[i].max(1) as f64,
            if i == 0 { "  (first step: cache is empty)" } else { "" }
        );
    }
    let mean: f64 = (1..lambdas.len())
        .map(|i| warm[i] as f64 / cold[i].max(1) as f64)
        .sum::<f64>()
        / (lambdas.len() - 1) as f64;
    println!("\nmean warm/cold iteration ratio over steps 1+: {mean:.3}");
    Ok(())
}
