//! Drive a warm-started λ-sweep against a flexa HTTP server over
//! loopback: submit eight Lasso jobs that share one generated `(A, b)`
//! (the `lambda` spec key reweights the regularizer without
//! regenerating), watch each job's SSE stream to its `finished` event,
//! then read `/metrics` and report the warm-start cache hits.
//!
//! * `FLEXA_HTTP_ADDR=127.0.0.1:PORT` — talk to an already-running
//!   `flexa serve --http` (this is how the CI smoke step uses it).
//! * unset — spin up an in-process server on an ephemeral port first.
//! * `FLEXA_HTTP_TOKEN=...` — authenticate every request with this
//!   bearer token (multi-tenant servers; see `flexa serve --tenants`).
//! * `FLEXA_HTTP_PROBE_UNAUTHORIZED=1` — additionally submit one job
//!   with a bogus token and require a `401`.
//! * `FLEXA_HTTP_PROBE_QUOTA_TOKEN=...` — additionally submit one job
//!   as this tenant and require a `429` with `Retry-After` (point it at
//!   a tenant configured with `max_queued = 0`).
//!
//! Run: `cargo run --release --example http_client`
//!
//! Exits non-zero if any job fails to reach `finished`, the SSE
//! lifecycle is incomplete, `/metrics` shows no cache hit, or an
//! enabled probe sees the wrong status.

use anyhow::{anyhow, ensure, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// One `Connection: close` HTTP exchange; returns (status, body).
/// `auth` overrides the ambient `FLEXA_HTTP_TOKEN` (Some("") = send no
/// credentials even if the env var is set).
fn request_as(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    auth: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect to {addr}"))?;
    // Fail with a diagnostic instead of hanging CI if the server wedges
    // (SSE heartbeats arrive every ~200ms, so 60s of silence is dead).
    stream.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    let token = match auth {
        Some(t) => t.to_string(),
        None => std::env::var("FLEXA_HTTP_TOKEN").unwrap_or_default(),
    };
    if !token.is_empty() {
        head.push_str(&format!("Authorization: Bearer {token}\r\n"));
    }
    if let Some(b) = body {
        head.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    if let Some(b) = body {
        stream.write_all(b.as_bytes())?;
    }
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed response: {raw:.80}"))?;
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    Ok((status, body))
}

fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    request_as(addr, method, path, body, None)
}

/// Stream `/v1/jobs/{id}/events` until the `finished` frame; returns the
/// terminal outcome label and the number of iteration frames seen.
fn watch_sse(addr: &str, job: u64) -> Result<(String, usize)> {
    let (status, body) = request(addr, "GET", &format!("/v1/jobs/{job}/events"), None)?;
    ensure!(status == 200, "SSE stream for job {job}: HTTP {status}");
    let mut iterations = 0usize;
    let mut outcome = None;
    let mut lines = body.lines();
    while let Some(line) = lines.next() {
        if line == "event: iteration" {
            iterations += 1;
        } else if line == "event: finished" {
            // The `data:` line follows; pull the outcome label out of it.
            while let Some(data) = lines.next() {
                if let Some(json) = data.strip_prefix("data: ") {
                    let doc = flexa::serve::Json::parse(json)?;
                    outcome = doc.get("outcome").and_then(|v| v.as_str()).map(str::to_string);
                    break;
                }
            }
        }
    }
    let outcome = outcome.ok_or_else(|| anyhow!("job {job}: no finished event in SSE stream"))?;
    Ok((outcome, iterations))
}

fn job_id_of(body: &str) -> Result<u64> {
    let doc = flexa::serve::Json::parse(body)?;
    doc.get("job")
        .and_then(|v| v.as_f64())
        .map(|v| v as u64)
        .ok_or_else(|| anyhow!("no job id in response: {body}"))
}

fn main() -> Result<()> {
    // Use an external server when pointed at one, else self-host.
    let (addr, server) = match std::env::var("FLEXA_HTTP_ADDR") {
        Ok(a) => (a, None),
        Err(_) => {
            let server = flexa::http::HttpServer::bind(
                "127.0.0.1:0",
                flexa::http::HttpConfig::default(),
                flexa::serve::ServeConfig::default().with_workers(1),
                flexa::api::Registry::with_defaults(),
            )?
            .spawn();
            let addr = server.addr().to_string();
            println!("self-hosted flexa http server on {addr}");
            (addr, Some(server))
        }
    };

    let (status, _) = request(&addr, "GET", "/healthz", None)?;
    ensure!(status == 200, "/healthz returned HTTP {status}");
    println!("healthz: ok");

    // Optional tenant-plane probes (driven by the CI tenant-smoke job).
    let tiny = "{\"rows\":15,\"cols\":45,\"max_iters\":5,\"target\":0}";
    if std::env::var_os("FLEXA_HTTP_PROBE_UNAUTHORIZED").is_some() {
        let (status, body) =
            request_as(&addr, "POST", "/v1/jobs", Some(tiny), Some("definitely-not-a-token"))?;
        ensure!(status == 401, "bogus token: expected 401, got {status}: {body}");
        println!("probe unauthorized: 401 as expected");
    }
    if let Ok(token) = std::env::var("FLEXA_HTTP_PROBE_QUOTA_TOKEN") {
        let (status, body) =
            request_as(&addr, "POST", "/v1/jobs", Some(tiny), Some(token.as_str()))?;
        ensure!(status == 429, "over-quota tenant: expected 429, got {status}: {body}");
        ensure!(body.contains("quota"), "429 body should name the quota: {body}");
        println!("probe over-quota: 429 as expected");
    }

    // Eight λ points over one shared (A, b): same rows/cols/seed, only
    // `lambda` varies, so every job after the first warm-starts from its
    // predecessor's solution.
    let lambdas: Vec<f64> = (0..8).map(|i| 2.0 * 0.7f64.powi(i)).collect();
    println!("\n{:>10} {:>6} {:>10} {:>12}", "lambda", "job", "outcome", "iterations");
    for (i, lambda) in lambdas.iter().enumerate() {
        let spec = format!(
            "{{\"problem\":\"lasso\",\"rows\":60,\"cols\":180,\"seed\":7,\"lambda\":{lambda},\
             \"algo\":\"fpa\",\"max_iters\":300,\"warm_start\":true,\"tag\":\"sweep-{i}\"}}"
        );
        let (status, body) = request(&addr, "POST", "/v1/jobs", Some(&spec))?;
        ensure!(status == 202, "POST /v1/jobs: HTTP {status}: {body}");
        let job = job_id_of(&body)?;
        let (outcome, iterations) = watch_sse(&addr, job)?;
        ensure!(outcome == "done", "job {job} (lambda {lambda}): outcome `{outcome}`");
        println!("{lambda:>10.4} {job:>6} {outcome:>10} {iterations:>12}");
    }

    let (status, metrics) = request(&addr, "GET", "/metrics", None)?;
    ensure!(status == 200, "/metrics returned HTTP {status}");
    let cache_hits: u64 = metrics
        .lines()
        .find(|l| l.starts_with("flexa_cache_hits_total "))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| anyhow!("no flexa_cache_hits_total in /metrics"))?;
    println!("\nwarm-start cache hits: {cache_hits}");
    for line in metrics.lines().filter(|l| {
        l.starts_with("flexa_jobs_submitted_total ")
            || l.starts_with("flexa_jobs_finished_total{outcome=\"done\"}")
            || l.starts_with("flexa_cache_misses_total ")
    }) {
        println!("  {line}");
    }
    ensure!(cache_hits >= 1, "a λ-sweep over shared data must hit the warm-start cache");

    if let Some(server) = server {
        let (results, _stats) = server.shutdown()?;
        println!("server drained with {} results", results.len());
    }
    println!("OK");
    Ok(())
}
