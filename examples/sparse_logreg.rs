//! Sparse logistic regression (paper §2, fourth bullet):
//! `min Σⱼ log(1 + exp(−aⱼ yⱼᵀx)) + c‖x‖₁`.
//!
//! Exercises the framework on a *non-quadratic* smooth loss: FPA uses
//! the diagonal second-order surrogate (a valid `Pᵢ` satisfying P1–P3)
//! and still converges per Theorem 1. Reports classification accuracy
//! and support recovery against the generating hyperplane.
//!
//! Run: `cargo run --release --example sparse_logreg`

use flexa::algos::fista::Fista;
use flexa::algos::fpa::Fpa;
use flexa::algos::{SolveOptions, Solver};
use flexa::datagen::SparseClassification;
use flexa::linalg::{ops, MatVec};
use flexa::problems::logreg::SparseLogReg;

fn main() {
    let (samples, features) = (600, 1500);
    let gen = SparseClassification::new(samples, features, 0.05)
        .seed(23)
        .label_noise(0.02);
    let inst = gen.generate();
    let w_true = inst.w_true.clone();
    println!(
        "sparse logistic regression: {samples} samples, {features} features, true support = {}",
        ops::nnz(&w_true, 0.0)
    );

    let problem = SparseLogReg::new(inst.m, 2.0);
    let opts = SolveOptions {
        max_iters: 3000,
        max_seconds: 60.0,
        target_rel_err: 0.0, // no planted V*: run to budget
        ..Default::default()
    };

    let fpa = Fpa::paper_defaults(&problem).solve(&problem, &opts);
    let fista = Fista::default().solve(&problem, &opts);

    for (name, r) in [("fpa", &fpa), ("fista", &fista)] {
        // Label-scaled margins: row i of M is a_i * y_i, so a correct
        // prediction is margin > 0.
        let mut z = vec![0.0; samples];
        problem.margins(&r.x, &mut z);
        let correct = z.iter().filter(|&&zi| zi > 0.0).count();
        println!(
            "  {name:<6} V = {:.4}  train acc = {:.1}%  ‖x‖₀ = {}  iters = {}  t = {:.2}s",
            r.objective,
            100.0 * correct as f64 / samples as f64,
            ops::nnz(&r.x, 1e-6),
            r.iterations,
            r.trace.last().map(|l| l.time_s).unwrap_or(0.0)
        );
    }

    // Support recovery vs the generating hyperplane.
    let recovered = fpa
        .x
        .iter()
        .zip(&w_true)
        .filter(|(xi, wi)| (xi.abs() > 1e-6) && (wi.abs() > 0.0))
        .count();
    println!(
        "FPA recovered {recovered} of {} true-support coordinates",
        ops::nnz(&w_true, 0.0)
    );
}
