//! Sparse logistic regression (paper §2, fourth bullet):
//! `min Σⱼ log(1 + exp(−aⱼ yⱼᵀx)) + c‖x‖₁`.
//!
//! Exercises the framework on a *non-quadratic* smooth loss through the
//! session API: the `logreg` registry problem runs against FPA (diagonal
//! second-order surrogate, a valid `Pᵢ` satisfying P1–P3) and FISTA.
//! Reports classification accuracy and support recovery against the
//! generating hyperplane — the spec-driven generators are deterministic,
//! so the evaluation rebuilds the same instance outside the session.
//!
//! Run: `cargo run --release --example sparse_logreg`

use flexa::algos::SolveOptions;
use flexa::api::{ProblemSpec, Session};
use flexa::datagen::SparseClassification;
use flexa::linalg::{ops, MatVec};

fn main() -> anyhow::Result<()> {
    let (samples, features) = (600, 1500);
    let spec = ProblemSpec::logreg(samples, features)
        .with_sparsity(0.05)
        .with_c(2.0)
        .with_seed(23)
        .with_label_noise(0.02);

    // The same deterministic instance the registry builds, regenerated
    // here for the evaluation (margins + support recovery).
    let inst = SparseClassification::new(samples, features, 0.05)
        .seed(23)
        .label_noise(0.02)
        .generate();
    let w_true = inst.w_true.clone();
    println!(
        "sparse logistic regression: {samples} samples, {features} features, true support = {}",
        ops::nnz(&w_true, 0.0)
    );

    let opts = SolveOptions::default()
        .with_max_iters(3000)
        .with_max_seconds(60.0)
        .with_target(0.0); // no planted V*: run to budget

    let mut runs = Vec::new();
    for algo in ["fpa", "fista"] {
        let run = Session::problem(spec.clone()).solver_named(algo)?.options(opts.clone()).run()?;
        runs.push((algo, run));
    }

    for (name, r) in &runs {
        // Label-scaled margins: row i of M is a_i * y_i, so a correct
        // prediction is margin > 0.
        let mut z = vec![0.0; samples];
        inst.m.matvec(&r.x, &mut z);
        let correct = z.iter().filter(|&&zi| zi > 0.0).count();
        println!(
            "  {name:<6} V = {:.4}  train acc = {:.1}%  ‖x‖₀ = {}  iters = {}  t = {:.2}s",
            r.objective,
            100.0 * correct as f64 / samples as f64,
            ops::nnz(&r.x, 1e-6),
            r.iterations,
            r.report.trace.last().map(|l| l.time_s).unwrap_or(0.0)
        );
    }

    // Support recovery vs the generating hyperplane.
    let fpa = &runs[0].1;
    let recovered = fpa
        .x
        .iter()
        .zip(&w_true)
        .filter(|(xi, wi)| (xi.abs() > 1e-6) && (wi.abs() > 0.0))
        .count();
    println!(
        "FPA recovered {recovered} of {} true-support coordinates",
        ops::nnz(&w_true, 0.0)
    );
    Ok(())
}
